"""Device-resident federated round engine (paper Sec. II-A, eqs. 2-7).

One jitted ``round_step`` executes an entire FedSGD round on device over the
packed ``[R, 128]`` parameter buffer (core/packing.py):

  1. importance Q = (w * v)^2 (eq. 4) over the packed buffer;
  2. the global pruning threshold — the k-th smallest prunable importance,
     k = floor(lambda * M_prunable) — via an on-device exponent-histogram +
     binary search over fp32 bit patterns (`kth_smallest_threshold`; no
     sort, no host `np.partition`, no device->host parameter transfer);
  3. fused importance+keep-mask Pallas launch (kernels/pruning_mask.py) —
     one kernel for the whole model instead of one per leaf; when every
     selected client shares lambda the threshold and mask are computed once
     (no per-client recompute), otherwise the batched kernel emits all
     per-client masks from a single read of (w, v);
  4. per-client mini-batch gradients on the pruned model (eq. 5) over the
     stacked client batches — gradients are taken directly with respect to
     the packed buffer (unpacking is differentiable) and masked on device
     (pruned coordinates are never "uploaded");
  5. fused weighted aggregate+update launch: combine the stacked gradients
     with per-client 0/1 weights (eq. 6) and take the FedSGD step (eq. 7)
     in one pass; the mean gradient doubles as the next round's broadcast v.

Shape stability (no retrace storms)
-----------------------------------
Schedules from `solve_p1` select a different client count C every round,
and a naive jit retraces `round_step` per distinct C. The engine instead
pads the client axis to a *bucket* size — ``shards * next_pow2(ceil(C /
shards))`` — and threads a per-client validity weight ``cw[C_pad]`` (1 for
real clients, 0 for padding) through the weighted aggregate, so a whole
training run compiles at most ``log2(C_max)+1`` traces per lambda family
(`n_traces` counts them; tests assert the bound). Padding clients replicate
the last real client's batch and are skipped in the aggregate via
``where(cw > 0, acc + cw*g, acc)`` — they can never perturb the update,
not even by a NaN.

Ragged clients (fewer samples than the batch size) are handled one level
down with the same trick: the trainer pads the *sample* axis and passes
per-sample 0/1 weights consumed by a weighted loss (`sample_weights`), so
stragglers stay on the packed path (see core/federated.py — the weighted
mean with 0/1 weights is the plain mean over the real samples).

Multi-device sharding
---------------------
With more than one local device (or ``REPRO_ROUND_SHARDS``), the client
axis of steps 4-5 is sharded over the ``data`` axis of a host mesh
(`launch/mesh.make_host_mesh`, model=1) via `shard_map`: parameters, the
global gradient, and the mask are replicated; each shard scans its local
clients and reduces a weighted *partial sum* of masked gradients; a single
in-graph `psum` per round combines the partials, feeding the fused FedSGD
update computed redundantly (replicated) on every device. Parameters stay
device-resident and replicated round over round — one collective per
round, nothing syncs to host. CPU tests force a multi-device host with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (scripts/test.sh
sharded leg).

Numerics
--------
The single-device bucketed path reproduces the reference trainer
**bit-for-bit** on fp32 models (tests/test_packing.py): with 0/1 weights
the weighted aggregate accumulates the real clients in reference order
(`acc + 1.0*g` is exact) and the update's `eta*g` product is fenced from
FMA contraction (`kernels/ops.rounded_step`). The sharded path reassociates
only the cross-shard reduction (per-shard partials + psum), so it is
trajectory-equivalent within ~1 ulp per round, not bit-identical. Only the
integers k = floor(lambda * M_prunable) and the scalar 1/C are computed on
host (O(1) arithmetic on the schedule); parameters never leave the device.

With ``donate=True`` (used by `FederatedTrainer`, which owns the buffers)
the parameter / global-gradient buffers are donated to the step on
accelerator backends and updated in place round over round; the default
keeps ``round_step`` purely functional.

Multi-round blocks (``block_step``)
-----------------------------------
``round_step`` still pays one dispatch + one stacked-batch host->device
upload per round. ``block_step`` removes both: client datasets live on
device in a `ClientStore` (core/client_store.py), batches are gathered on
device from host-drawn index arrays ``[K, C, B]`` (the indices come from
the trainer's existing numpy RNG, so the batch sequence — and bit-for-bit
parity — is preserved), the schedule is stacked into ``[K]``-leading
arrays (client ids, ks, client weights, 1/C), and a `lax.scan` over the
round axis runs K rounds in ONE jitted dispatch, carrying (w, v). Per-round
losses come back as a ``[K, C_b]`` device array that drops into the
trainer's lazy-materialization path. K is bucketed the same power-of-two
way as the client axis (the trainer decomposes arbitrary block lengths into
pow2 chunks instead of padding — padded rounds would cost full gradient
FLOPs), so AO-driven varying (C, K, lambda) schedules stay within
``(log2(C_max)+1) * (log2(K_max)+1)`` traces per lambda family
(`n_traces` / `buckets_used` / `k_buckets_used` account for it). On a mesh
each scan step wraps the same shard_map region the per-round sharded path
uses — still exactly one `psum` per round, with the store replicated so
every device gathers from local memory.
"""
from __future__ import annotations

import os
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.packing import ParamPack
from repro.kernels import ops

PyTree = Any


def kth_smallest_threshold(q: jnp.ndarray, prunable: jnp.ndarray,
                           k: jnp.ndarray, *,
                           coarse: str | None = None,
                           hist_impl: str = "auto") -> jnp.ndarray:
    """Threshold such that exactly k prunable entries are strictly below it.

    Matches `pruning.global_threshold` bit-for-bit: the k-th smallest
    prunable importance, nudged one ulp up (`nextafter`), computed entirely
    on device. `k` may be a scalar or a [C] vector of per-client counts
    (one pass amortized across clients).

    Exact selection without a sort: importance scores are non-negative, and
    for non-negative IEEE-754 floats the value order equals the integer
    order of the bit patterns, so the k-th smallest is found by bisection
    over bit patterns with one masked count per step (O(n) per pass, no
    O(n log n) sort).

    `coarse="histogram"` prepends a 256-bin histogram over the *exponent
    byte* (``bits >> 23``; the sign bit is 0): one scan whose cumulative
    counts pin bits 30..23 of the answer, leaving a 23-step mantissa
    bisection — 24 data passes instead of 31. `"bisect"` is the plain
    31-step search. The default (None = auto) picks per backend: the
    histogram's scatter-add lowers to a fast on-chip accumulation on TPU
    but to a serial ~130 ns/element scatter on XLA:CPU — 3-7x slower than
    the seven count passes it saves (measured, see ROADMAP) — so CPU keeps
    the pure bisection. Both modes are exact and tested against the host
    oracle.

    `hist_impl` picks how the histogram pass is computed when
    ``coarse="histogram"``: "pallas" uses the tiled exponent-histogram
    kernel (per-block bin counts accumulated in VMEM scratch — no
    scatter-add; requires a packed [R, 128*k] layout), "xla" the
    scatter-add mirror, "auto" pallas on TPU and xla elsewhere
    (`kernels/ops.packed_exponent_histogram`).
    """
    if coarse is None:
        coarse = "histogram" if jax.default_backend() == "tpu" else "bisect"
    if coarse not in ("histogram", "bisect"):
        raise ValueError(f"unknown coarse mode {coarse!r}")
    bits = jax.lax.bitcast_convert_type(q.reshape(-1), jnp.int32)
    valid = prunable.reshape(-1) > 0
    k = jnp.asarray(k, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2   # (lo+hi)//2 overflows int32 for q >= 2.0
        below = jnp.where(valid, bits[..., :] <= mid[..., None], False)
        ge = below.sum(axis=-1) >= k
        return jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi)

    if coarse == "histogram":
        # pass 1/24: exponent-byte histogram; cum[b] = #valid, top byte <= b.
        # The k-th smallest lives in the first bin whose cumulative count
        # reaches k, which pins bits 30..23 of the answer in one data scan.
        hist = ops.packed_exponent_histogram(q, prunable, impl=hist_impl)
        cum = jnp.cumsum(hist)
        # clamp: k beyond the valid count would return 256 and overflow the
        # shift; bin 255 then degrades to the same max-element answer the
        # pure bisection gives
        top = jnp.minimum(jnp.searchsorted(cum, k, side="left"),
                          255).astype(jnp.int32)
        lo0 = top << 23
        hi0 = lo0 | jnp.int32((1 << 23) - 1)
        steps = 23
    else:
        lo0 = jnp.zeros(k.shape, jnp.int32)
        hi0 = jnp.full(k.shape, jnp.int32(2**31 - 1))
        steps = 31
    lo, _ = jax.lax.fori_loop(0, steps, body, (lo0, hi0))
    kth = jax.lax.bitcast_convert_type(lo, jnp.float32)
    return jnp.where(k > 0, jnp.nextafter(kth, jnp.inf),
                     -jnp.asarray(jnp.inf, jnp.float32))


def bucket_capacity(n_clients: int, *, shards: int = 1, bucket: bool = True,
                    max_clients: int | None = None) -> int:
    """Padded client-axis size for a round selecting `n_clients` — the one
    bucketing formula, shared by `RoundEngine.bucket_size` and the eager
    reference robust reducer (core/federated.py pads its client stack to
    the same capacity so packed-vs-reference stays bitwise comparable on
    rank-based aggregators)."""
    per = -(-int(n_clients) // shards)
    if bucket:
        p2 = 1 << (per - 1).bit_length()
        if max_clients is not None:
            p2 = min(p2, max(per, -(-int(max_clients) // shards)))
        per = p2
    return per * shards


def resolve_shards(shards: int | None) -> int:
    """Data-shard count for the client axis: explicit arg, then the
    REPRO_ROUND_SHARDS env override (CPU tests under
    --xla_force_host_platform_device_count), then every local device.
    Public so callers that must know whether an engine will shard_map
    (e.g. the sweep service's collective-safety gate) resolve it the
    same way the engine will."""
    if shards is not None:
        return max(1, int(shards))         # explicit: let mesh build fail loud
    env = os.environ.get("REPRO_ROUND_SHARDS")
    if env:
        return min(max(1, int(env)), len(jax.devices()))
    return len(jax.devices())


_resolve_shards = resolve_shards


class RoundEngine:
    """Jitted packed-buffer FedSGD round (selection -> pruning -> aggregate).

    Parameters
    ----------
    loss_fn : loss(params_pytree, x, y) -> scalar; the engine differentiates
        it through `pack.unpack`, so gradients live on the packed buffer.
    pack : ParamPack describing the model layout.
    eta : FedSGD learning rate (compile-time constant).
    weighted_loss_fn : optional loss(params, x, y, sample_weights) -> scalar
        consuming per-sample 0/1 weights — required for ragged client
        batches to stay on the packed path (models.make_loss_fn attaches
        one as ``loss.weighted``). Without it sample weights are ignored.
    shards : client-axis shard count (None = REPRO_ROUND_SHARDS env, else
        all local devices; 1 disables sharding).
    bucket : pad the client axis to power-of-two per-shard buckets so
        varying selection sizes reuse compiles (True; False pads only to a
        multiple of the shard count).
    max_clients : total client population, if known (FederatedTrainer
        passes len(clients)). Caps the bucket ladder so full participation
        never pads past the population (e.g. C=20 of 20 buckets to 20, not
        32 — padding clients cost real gradient FLOPs).
    aggregator : optional core/aggregators.Aggregator — a Byzantine-robust
        reducer slotted in place of the weighted mean behind the same
        FMA-fenced update tail. None (the default) keeps the builtin mean
        path with byte-identical traces. A construction-time constant,
        like eta: it changes every round graph, so swapping it means a new
        engine (FederatedTrainer / Experiment.build handle pooling).
    """

    def __init__(self, loss_fn: Callable, pack: ParamPack, *, eta: float,
                 client_axis: str = "auto", kernel_impl: str = "auto",
                 donate: bool = False, weighted_loss_fn: Callable | None = None,
                 shards: int | None = None, bucket: bool = True,
                 max_clients: int | None = None, aggregator=None,
                 local_scheme=None):
        if client_axis not in ("auto", "unroll", "scan", "vmap"):
            raise ValueError(f"unknown client_axis {client_axis!r}")
        self.pack = pack
        self.eta = float(eta)
        self.client_axis = client_axis
        self.kernel_impl = kernel_impl
        self.bucket = bool(bucket)
        self.max_clients = int(max_clients) if max_clients else None
        self.aggregator = aggregator
        # local-update scheme (core/local.py): None = the single-gradient
        # FedSGD body (today's paths, byte-identical traces). A LocalScheme
        # swaps the client body for an inner lax.scan over E local steps —
        # a construction-time constant like eta/aggregator, so the step
        # axis pads to the STATIC pow2 bucket `steps_bucket` with a static
        # 0/1 step-validity vector (padded steps are exact no-ops and
        # consume no RNG), keeping the trace-family ladder bounded.
        self.local_scheme = local_scheme
        if local_scheme is not None:
            eb = local_scheme.steps_bucket
            self._sv = jnp.asarray(
                (np.arange(eb) < local_scheme.steps).astype(np.float32))
        else:
            self._sv = None
        self.shards = resolve_shards(shards)
        self.prunable = jnp.asarray(pack.prunable_mask())
        # compile accounting: one increment per (re)trace of a step impl —
        # bucketing bounds this by the number of distinct bucket sizes per
        # lambda family regardless of how C varies round to round
        self.n_traces = 0
        self.buckets_used: set[int] = set()
        self.k_buckets_used: set[int] = set()
        # device-array caches for the per-round / per-block auxiliary
        # inputs: all-ones sample weights by shape (block keys tagged
        # "blk" — same shape family, different rank) and the per-round
        # path's 0/1 client weights by (bucket, selected count). Both key
        # sets are bounded by the bucket ladder; block client weights are
        # instead derived on device from the [K] counts array (a cache
        # keyed by the full counts tuple would almost never hit under an
        # AO schedule and would grow without bound).
        self._sw_cache: dict[tuple, jnp.ndarray] = {}
        self._cw_cache: dict[tuple, jnp.ndarray] = {}

        if self.shards > 1:
            # client axis sharded over the data axis of a host mesh; layered
            # under launch/ so importing core never touches device state
            from repro.launch.mesh import make_host_mesh
            self.mesh = make_host_mesh(model=1, data=self.shards)
        else:
            self.mesh = None

        if weighted_loss_fn is not None:
            def packed_loss(wp, x, y, sw):
                return weighted_loss_fn(pack.unpack(wp), x, y, sw)
        else:
            def packed_loss(wp, x, y, sw):
                return loss_fn(pack.unpack(wp), x, y)

        self._value_and_grad = jax.value_and_grad(packed_loss)
        # donate=True lets XLA update the parameter / global-gradient
        # buffers in place on accelerators, but the caller must then treat
        # the passed-in (w, v) as consumed — reading them after round_step
        # raises a deleted-buffer error. Only enable it for owners of the
        # buffers (FederatedTrainer does); the default keeps round_step
        # purely functional. CPU does not implement donation, so skip it
        # there to avoid per-compile warnings.
        donate_args = ((0, 1) if donate
                       and jax.default_backend() in ("tpu", "gpu") else ())
        self._donate_args = donate_args
        # Fault-injection entry points (dropout rides the plain entries via
        # host-folded client weights; corruption and block fault masks need
        # extra traced operands) are built LAZILY per (kind, noisy) so
        # fault-free runs never pay their jit scaffolding.
        self._fault_steps: dict[tuple, Callable] = {}
        # survivor count of the most recent round_step ([] scalar) or
        # block_step ([K]) — weighted clients whose summed gradient passed
        # the isfinite guard; the trainer materializes it lazily alongside
        # the losses to drive the n_quarantined / n_skipped_rounds counters
        self.last_n_ok = None
        # robust-aggregation diagnostic of the most recent dispatch
        # ([] scalar / [K] int32; constant 0 on the mean path) — clients
        # trimmed / clipped / excluded, same lazy materialization contract
        self.last_agg_stat = None
        # FedDyn only: the updated per-client correction state [C, R, L]
        # of the most recent dispatch — stays on device; the trainer (the
        # buffer's owner) adopts it after each step
        self.last_h = None
        if self.mesh is None:
            round_shared, round_multi = self._round_shared, self._round_multi
            self._step_shared = jax.jit(self._shared_impl,
                                        donate_argnums=donate_args)
            self._step_multi = jax.jit(self._multi_impl,
                                       donate_argnums=donate_args)
        else:
            round_shared = self._round_shared_sharded
            round_multi = self._round_multi_sharded
            self._step_shared = jax.jit(self._shared_sharded_impl,
                                        donate_argnums=donate_args)
            self._step_multi = jax.jit(self._multi_sharded_impl,
                                       donate_argnums=donate_args)
        # block dispatches wrap the SAME per-round bodies in the scan
        # scaffold, so block and per-round modes can never diverge
        self._blk_shared = jax.jit(self._make_block_impl(round_shared),
                                   donate_argnums=donate_args)
        self._blk_multi = jax.jit(self._make_block_impl(round_multi),
                                  donate_argnums=donate_args)

        # Noisy-aggregation variants: separate jit entry points (the noise
        # operand changes the traced graph), wrapping the same round
        # bodies, so the noiseless traces stay byte-identical to before.
        def _noisy_step(fn):
            def impl(w, v, xs, ys, sw, cw, inv, k, noise):
                self.n_traces += 1
                return fn(w, v, xs, ys, sw, cw, inv, k, noise=noise)
            return impl

        self._step_shared_nz = jax.jit(_noisy_step(round_shared),
                                       donate_argnums=donate_args)
        self._step_multi_nz = jax.jit(_noisy_step(round_multi),
                                      donate_argnums=donate_args)
        self._blk_shared_nz = jax.jit(
            self._make_block_impl(round_shared, noisy=True),
            donate_argnums=donate_args)
        self._blk_multi_nz = jax.jit(
            self._make_block_impl(round_multi, noisy=True),
            donate_argnums=donate_args)

    # -- jitted bodies ------------------------------------------------------

    @property
    def _axis(self) -> str:
        # "auto" = scan: O(1) program size in the client count, and it
        # empirically beats the unrolled loop once the whole round is fused
        # into one program, with the same bit-for-bit results.
        return "scan" if self.client_axis == "auto" else self.client_axis

    def _grads_shared(self, pruned, mask, xs, ys, sw):
        """Shared-lambda client axis: every client sees the same pruned
        buffer / mask [R, L] (never materialized per client). sw [C, B] are
        per-sample weights for the weighted loss. Returns (losses [C],
        masked grads [C, R, L])."""
        n_clients = xs.shape[0]
        ax = self._axis
        if ax == "unroll":
            out = [self._value_and_grad(pruned, xs[c], ys[c], sw[c])
                   for c in range(n_clients)]
            return (jnp.stack([l for l, _ in out]),
                    jnp.stack([g * mask for _, g in out]))
        if ax == "vmap":
            losses, grads = jax.vmap(
                lambda x, y, s: self._value_and_grad(pruned, x, y, s))(
                    xs, ys, sw)
            return losses, grads * mask

        def body(carry, inp):
            x, y, s = inp
            loss, g = self._value_and_grad(pruned, x, y, s)
            return carry, (loss, g * mask)

        _, (losses, grads) = jax.lax.scan(body, 0.0, (xs, ys, sw))
        return losses, grads

    def _grads_multi(self, w, masks, xs, ys, sw):
        """Per-client-lambda client axis: masks are [C, R, L]. Each client's
        pruned buffer w * masks[c] is formed inside its own step so the
        [C, R, L] stack of pruned models is never materialized."""
        n_clients = xs.shape[0]
        ax = self._axis
        if ax == "unroll":
            out = [self._value_and_grad(w * masks[c], xs[c], ys[c], sw[c])
                   for c in range(n_clients)]
            return (jnp.stack([l for l, _ in out]),
                    jnp.stack([g * masks[c] for c, (_, g) in enumerate(out)]))
        if ax == "vmap":
            losses, grads = jax.vmap(
                lambda m, x, y, s: self._value_and_grad(w * m, x, y, s))(
                    masks, xs, ys, sw)
            return losses, grads * masks

        def body(carry, inp):
            m, x, y, s = inp
            loss, g = self._value_and_grad(w * m, x, y, s)
            return carry, (loss, g * m)

        _, (losses, grads) = jax.lax.scan(body, 0.0, (masks, xs, ys, sw))
        return losses, grads

    # -- local-update scheme bodies (DESIGN.md §14) -------------------------
    #
    # With a LocalScheme the per-client body becomes an inner lax.scan over
    # the pow2-bucketed step axis: each step takes a masked gradient at the
    # CURRENT iterate, folds in the scheme's regularizer (FMA-fenced, so
    # the eager reference's per-op rounding is reproduced bit for bit),
    # accumulates the update direction into the upload, and steps the local
    # iterate. Padded steps (t >= E) are gated off by the static 0/1
    # validity vector — exact no-ops on (u, acc) via `where`, and they
    # replicate the last real step's batch so they consume no RNG.

    def _local_client(self, u0, mask, xs, ys, sw, hm=None):
        """One client's local trajectory. xs: [E_b, B, ...]; u0 the pruned
        start w*mask; hm the client's masked FedDyn correction state (or
        None). Returns (loss at step 0, upload = sum of step directions,
        FedDyn state delta or None).

        The upload accumulator starts at zeros, so every scheme's upload is
        `0 + d_0 + ...` — the add normalizes -0.0 direction coordinates to
        +0.0, and the eager reference accumulates from zeros the same way.
        """
        scheme = self.local_scheme
        coeff = scheme.coeff

        def body(carry, inp):
            u, acc = carry
            x, y, s, valid = inp
            loss, g = self._value_and_grad(u, x, y, s)
            g = g * mask
            if scheme.name == "fedavg":
                d = g
            else:
                d = ops.packed_local_delta(g, u, u0, coeff, hm=hm)
            acc = jnp.where(valid > 0, acc + d, acc)
            u = jnp.where(valid > 0,
                          u - ops.rounded_step(self.eta, d), u)
            return (u, acc), loss

        (u_e, upload), losses = jax.lax.scan(
            body, (u0, jnp.zeros_like(u0)), (xs, ys, sw, self._sv))
        if scheme.stateful:
            # FedDyn server-side state delta: h_i <- h_i - alpha*(u_E - u0),
            # the product fenced exactly like the per-step regularizer
            hd = ops.rounded_step(jnp.float32(scheme.alpha), u_e - u0)
            return losses[0], upload, hd
        return losses[0], upload, None

    def _locals_shared(self, pruned, mask, xs, ys, sw, hcs=None):
        """Shared-lambda local-step client axis (xs: [C, E_b, B, ...]).
        Returns (losses [C], uploads [C, R, L], hds [C, R, L] | None).
        hcs: per-selected-client FedDyn state [C, R, L] (or None); the
        mask multiply below is exact (mask is 0/1)."""
        hms = None if hcs is None else hcs * mask
        n_clients = xs.shape[0]
        ax = self._axis
        if ax == "unroll":
            out = [self._local_client(pruned, mask, xs[c], ys[c], sw[c],
                                      None if hms is None else hms[c])
                   for c in range(n_clients)]
            return tuple(None if out[0][i] is None
                         else jnp.stack([o[i] for o in out])
                         for i in range(3))
        if ax == "vmap":
            if hms is None:
                return jax.vmap(
                    lambda x, y, s: self._local_client(
                        pruned, mask, x, y, s))(xs, ys, sw)
            return jax.vmap(
                lambda x, y, s, hm: self._local_client(
                    pruned, mask, x, y, s, hm))(xs, ys, sw, hms)

        def body(carry, inp):
            x, y, s, hm = inp
            return carry, self._local_client(pruned, mask, x, y, s, hm)

        _, out = jax.lax.scan(body, 0.0, (xs, ys, sw, hms))
        return out

    def _locals_multi(self, w, masks, xs, ys, sw, hcs=None):
        """Per-client-lambda local-step client axis: each client's pruned
        start w*masks[c] is formed inside its own step (the [C, R, L] stack
        of pruned models is never materialized)."""
        hms = None if hcs is None else hcs * masks
        n_clients = xs.shape[0]
        ax = self._axis
        if ax == "unroll":
            out = [self._local_client(w * masks[c], masks[c], xs[c], ys[c],
                                      sw[c],
                                      None if hms is None else hms[c])
                   for c in range(n_clients)]
            return tuple(None if out[0][i] is None
                         else jnp.stack([o[i] for o in out])
                         for i in range(3))
        if ax == "vmap":
            if hms is None:
                return jax.vmap(
                    lambda m, x, y, s: self._local_client(
                        w * m, m, x, y, s))(masks, xs, ys, sw)
            return jax.vmap(
                lambda m, x, y, s, hm: self._local_client(
                    w * m, m, x, y, s, hm))(masks, xs, ys, sw, hms)

        def body(carry, inp):
            m, x, y, s, hm = inp
            return carry, self._local_client(w * m, m, x, y, s, hm)

        _, out = jax.lax.scan(body, 0.0, (masks, xs, ys, sw, hms))
        return out

    def _client_grads_shared(self, pruned, mask, xs, ys, sw):
        """Client body dispatch for the STATELESS schemes: the plain
        single-gradient body when no LocalScheme is set (today's traces,
        byte-identical), otherwise the local-step body with the FedDyn
        state path unused. FedDyn routes through the dyn round bodies
        instead (extra h/cid operands)."""
        if self.local_scheme is None:
            return self._grads_shared(pruned, mask, xs, ys, sw)
        losses, uploads, _ = self._locals_shared(pruned, mask, xs, ys, sw)
        return losses, uploads

    def _client_grads_multi(self, w, masks, xs, ys, sw):
        if self.local_scheme is None:
            return self._grads_multi(w, masks, xs, ys, sw)
        losses, uploads, _ = self._locals_multi(w, masks, xs, ys, sw)
        return losses, uploads

    def _aggregate_update(self, w, v, grads, cw, inv, noise, cf=None,
                          poison=None):
        """Weighted aggregate + FedSGD tail, with graceful degradation and
        an optional noisy aggregation channel.

        `cf` (optional [C] per-client corruption factors, 1.0 = clean)
        scales each client's masked gradient before aggregation — the
        corrupt-upload fault axis (core/faults.py); a `1.0 * g` multiply
        is exact, so clean clients are bitwise unaffected. `poison`
        (optional [C, R, L] additive upload poison, zero = clean) is added
        after scaling — the GaussianPoison attack; note a clean client's
        `g + 0.0` normalizes -0.0 coordinates to +0.0, which the eager
        reference applies identically, so parity holds.

        The always-on non-finite guard (ops.packed_client_quarantine) then
        zeroes the weight of any client whose summed gradient went
        non-finite and renormalizes the mean over the survivors; with
        every upload finite it passes (cw, inv) through value-identically,
        so the default path stays bit-for-bit (tests/test_golden.py is the
        sensor). When NO client survives, `alive` selects the carried
        (w, v) — the round's update is skipped entirely, params unchanged.

        With a robust `aggregator` the quarantined weights feed
        `Aggregator.reduce` over the full stack instead of the weighted
        mean: the reducer emits a survivor-normalized aggregate plus its
        diagnostic count, applied through the same FMA-fenced tail with
        inv=1.0 (`ghat * 1.0` is exact, so the fence sequence is the
        bit-parity anchor on this path too).

        When `noise` (packed [R, L], zero on padding lanes) is traced in,
        the update consumes mean(g) + noise — the server never sees the
        clean aggregate (wireless/channel.py). The noiseless path keeps
        the fused kernel (the guard only rewrites its weight operands);
        the noisy path goes through the XLA mirror so the fenced mean
        product is materialized before the add (bit-parity with the eager
        reference sequence)."""
        if cf is not None:
            grads = grads * cf.astype(jnp.float32)[:, None, None]
        if poison is not None:
            grads = grads + poison.astype(jnp.float32)
        cw_eff, inv_eff, n_ok, alive = ops.packed_client_quarantine(
            grads, cw, inv)
        if self.aggregator is not None:
            ghat, ast = self.aggregator.reduce(grads, cw_eff)
            w2, g, step = ops.packed_apply_mean_update(
                w, ghat, jnp.float32(1.0), self.eta, noise=noise)
        elif noise is None:
            ast = jnp.int32(0)
            w2, g, step = ops.packed_fedsgd_update_weighted(
                w, grads, cw_eff, inv_eff, self.eta, impl=self.kernel_impl)
        else:
            ast = jnp.int32(0)
            gsum = ops.packed_weighted_grad_sum(grads, cw_eff)
            w2, g, step = ops.packed_apply_mean_update(w, gsum, inv_eff,
                                                       self.eta, noise=noise)
        # all clients faulted: carry params and the broadcast v unchanged
        # (the reference server_step's empty-grads early return)
        w2 = jnp.where(alive, w2, w)
        g = jnp.where(alive, g, v)
        # cw_eff rides along for the stateful schemes: FedDyn only updates
        # the correction state of clients whose (post-fault) upload arrived
        # finite — exactly the quarantine's surviving weights
        return w2, g, step, n_ok, ast, cw_eff

    def _round_shared(self, w, v, xs, ys, sw, cw, inv, k, noise=None,
                      cf=None, poison=None):
        """One shared-lambda round, given device batches — the single body
        traced by both the per-round jit and the block scan, so the two
        paths compile the identical round math (bit-for-bit contract)."""
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, k)
        _, mask = ops.packed_importance_mask(w, v, self.prunable, thr,
                                             impl=self.kernel_impl)
        pruned = w * mask
        losses, grads = self._client_grads_shared(pruned, mask, xs, ys, sw)
        # step stays an output of the jitted graph: see the weighted update
        w2, g, step, n_ok, ast, _ = self._aggregate_update(
            w, v, grads, cw, inv, noise, cf, poison)
        return w2, g, losses, thr, step, n_ok, ast

    def _round_multi(self, w, v, xs, ys, sw, cw, inv, ks, noise=None,
                     cf=None, poison=None):
        """One per-client-lambda round (see _round_shared)."""
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, ks)      # [C]
        _, masks = ops.packed_importance_masks(w, v, self.prunable, thr,
                                               impl=self.kernel_impl)
        losses, grads = self._client_grads_multi(w, masks, xs, ys, sw)
        w2, g, step, n_ok, ast, _ = self._aggregate_update(
            w, v, grads, cw, inv, noise, cf, poison)
        return w2, g, losses, thr, step, n_ok, ast

    def _shared_impl(self, w, v, xs, ys, sw, cw, inv, k):
        self.n_traces += 1
        return self._round_shared(w, v, xs, ys, sw, cw, inv, k)

    def _multi_impl(self, w, v, xs, ys, sw, cw, inv, ks):
        self.n_traces += 1
        return self._round_multi(w, v, xs, ys, sw, cw, inv, ks)

    # -- FedDyn round bodies: per-client correction state -------------------
    #
    # FedDyn threads two extra traced operands through the round: the full
    # per-client state h [C_all, R, L] (or a cohort slab on the streamed
    # path) and the selected ids cid [C_b] indexing its rows. The state of
    # the selected clients is gathered (exact copy), its masked value joins
    # each local step's direction, and after the aggregate the server
    # scatter-updates h_i <- h_i - alpha*(u_E - u0) for every client whose
    # upload arrived finite (the quarantine's cw_eff). Padding clients
    # replicate the last real id with a scatter contribution of exact +0.0
    # — a bitwise no-op, because h rows can never hold -0.0 (they start at
    # +0.0 and x + (-hd) only yields -0.0 from a -0.0 operand).

    def _h_scatter(self, h, cid, hds, cw_eff):
        upd = jnp.where(cw_eff[:, None, None] > 0, -hds, jnp.float32(0.0))
        return h.at[cid].add(upd)

    def _round_shared_dyn(self, w, v, xs, ys, sw, cw, inv, k, h, cid,
                          noise=None, cf=None, poison=None):
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, k)
        _, mask = ops.packed_importance_mask(w, v, self.prunable, thr,
                                             impl=self.kernel_impl)
        pruned = w * mask
        losses, uploads, hds = self._locals_shared(pruned, mask, xs, ys, sw,
                                                   h[cid])
        w2, g, step, n_ok, ast, cw_eff = self._aggregate_update(
            w, v, uploads, cw, inv, noise, cf, poison)
        h2 = self._h_scatter(h, cid, hds, cw_eff)
        return w2, g, losses, thr, step, n_ok, ast, h2

    def _round_multi_dyn(self, w, v, xs, ys, sw, cw, inv, ks, h, cid,
                         noise=None, cf=None, poison=None):
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, ks)      # [C]
        _, masks = ops.packed_importance_masks(w, v, self.prunable, thr,
                                               impl=self.kernel_impl)
        losses, uploads, hds = self._locals_multi(w, masks, xs, ys, sw,
                                                  h[cid])
        w2, g, step, n_ok, ast, cw_eff = self._aggregate_update(
            w, v, uploads, cw, inv, noise, cf, poison)
        h2 = self._h_scatter(h, cid, hds, cw_eff)
        return w2, g, losses, thr, step, n_ok, ast, h2

    # Mesh variants: state rows are gathered OUTSIDE the shard_map region
    # (h is replicated; the gather is exact and cheap) and enter sharded
    # along the client axis; inside, each shard runs its local clients'
    # step scans and the round's single collective becomes ONE tupled
    # all_gather of the raw (uploads, state deltas) stacks. The whole
    # aggregate tail — faults, quarantine, mean/robust reduce, update, h
    # scatter — then runs replicated on the gathered full-client stacks,
    # which makes the sharded FedDyn round BITWISE identical to the
    # unsharded one (same inputs, same ops — stronger than the mean path's
    # psum reassociation, same construction as the robust path).

    def _dyn_sharded_tail(self, w, v, ups, hds, cw, inv, h, cid, noise, cf,
                          poison):
        w2, g, step, n_ok, ast, cw_eff = self._aggregate_update(
            w, v, ups, cw, inv, noise, cf, poison)
        h2 = self._h_scatter(h, cid, hds, cw_eff)
        return w2, g, step, n_ok, ast, h2

    def _round_shared_dyn_sharded(self, w, v, xs, ys, sw, cw, inv, k, h,
                                  cid, noise=None, cf=None, poison=None):
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, k)
        _, mask = ops.packed_importance_mask(w, v, self.prunable, thr,
                                             impl=self.kernel_impl)
        pruned = w * mask
        hc = h[cid]

        def body(pruned_, mask_, xs_, ys_, sw_, hc_):
            losses, ups, hds = self._locals_shared(pruned_, mask_, xs_, ys_,
                                                   sw_, hc_)
            ga, hda = jax.lax.all_gather((ups, hds), "data", axis=0,
                                         tiled=True)
            return losses, ga, hda

        # gather-then-reduce is replicated by construction but invisible to
        # the static replication checker (see _robust_partial)
        losses, ups, hds = shard_map(
            body, mesh=self.mesh,
            in_specs=(P(), P(), P("data"), P("data"), P("data"), P("data")),
            out_specs=(P("data"), P(), P()), check_rep=False)(
                pruned, mask, xs, ys, sw, hc)
        w2, g, step, n_ok, ast, h2 = self._dyn_sharded_tail(
            w, v, ups, hds, cw, inv, h, cid, noise, cf, poison)
        return w2, g, losses, thr, step, n_ok, ast, h2

    def _round_multi_dyn_sharded(self, w, v, xs, ys, sw, cw, inv, ks, h,
                                 cid, noise=None, cf=None, poison=None):
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, ks)      # [C]
        hc = h[cid]

        def body(w_, v_, pr, thr_, xs_, ys_, sw_, hc_):
            _, masks = ops.packed_importance_masks(w_, v_, pr, thr_,
                                                   impl=self.kernel_impl)
            losses, ups, hds = self._locals_multi(w_, masks, xs_, ys_, sw_,
                                                  hc_)
            ga, hda = jax.lax.all_gather((ups, hds), "data", axis=0,
                                         tiled=True)
            return losses, ga, hda

        losses, ups, hds = shard_map(
            body, mesh=self.mesh,
            in_specs=(P(), P(), P(), P("data"), P("data"), P("data"),
                      P("data"), P("data")),
            out_specs=(P("data"), P(), P()), check_rep=False)(
                w, v, self.prunable, thr, xs, ys, sw, hc)
        w2, g, step, n_ok, ast, h2 = self._dyn_sharded_tail(
            w, v, ups, hds, cw, inv, h, cid, noise, cf, poison)
        return w2, g, losses, thr, step, n_ok, ast, h2

    # -- block scaffold: lax.scan over the round axis -----------------------

    def _make_block_impl(self, round_fn, noisy: bool = False,
                         faulted: bool = False, poisoned: bool = False,
                         sharded_store: bool = False, dyn: bool = False):
        """K rounds per dispatch around any of the four per-round bodies:
        the scan carries (w, v) and consumes [K]-leading stacked schedule
        arrays; batches are gathered ON DEVICE from the ClientStore
        buffers (dx, dy) via host-drawn indices (`ClientStore.gather` is
        the reference form of the same expression), so no batch data
        crosses host->device inside a block. One scaffold serves the
        shared/multi x unsharded/sharded grid — each scan step is exactly
        the corresponding per-round body, which is what makes a block
        bit-for-bit equal to K round_step dispatches. With ``noisy`` the
        scan additionally consumes a [K, R, L] per-round noise stack (one
        upload per BLOCK, not per round — the zero-per-round-H2D property
        is preserved). With ``faulted`` it consumes two more [K, C]
        schedule operands the same way: host-drawn 0/1 fault weights `fw`
        (multiplied into the counts-derived client weights — an exact 0/1
        product, so dropped clients ride the padding-client path) and
        per-client corruption factors `cf` (1.0 = clean, exact). With
        ``poisoned`` a [K, C, R, L] additive upload-poison stack joins them
        (zeros = clean) — the one block operand whose size scales with the
        model; still a single per-block upload, never per-round. With
        ``sharded_store`` (streamed cohorts on a mesh, core/cohort_store.py)
        the store buffers are sharded over the data axis instead of
        replicated and `cid` carries shard-LOCAL row ids, so the batch
        gather runs inside its own collective-free shard_map
        (`_gather_sharded`) — each device reads only its own clients' rows
        and the sharded round bodies consume the already-data-sharded
        batches unchanged. With ``dyn`` (FedDyn) the per-client correction
        state h joins the scan CARRY right after (w, v) — each round's
        scatter-update feeds the next round's gather, all inside the one
        dispatch — and the updated state is returned alongside (w', v')."""

        def impl(w, v, *op):
            self.n_traces += 1
            if dyn:
                h, op = op[0], op[1:]
            dx, dy, cids, idxs, sw, counts, inv, ks = op[:8]
            rest = op[8:]
            # 0/1 client-validity weights straight from the per-round real
            # counts — built on device (exact 0.0/1.0, so the weighted
            # aggregate is unchanged bit for bit), because host-building
            # them per block would mean an uncacheable [K, C_b] upload for
            # every distinct counts vector an AO schedule produces
            cw = (jnp.arange(cids.shape[1])[None, :]
                  < counts[:, None]).astype(jnp.float32)
            if faulted:
                fw, cf, rest = rest[0], rest[1], rest[2:]
                cw = cw * fw
            else:
                cf = None
            if poisoned:
                po, rest = rest[0], rest[1:]
            else:
                po = None

            def body(carry, inp):
                if dyn:
                    w, v, h = carry
                else:
                    w, v = carry
                cid, ix, sw_k, cw_k, inv_k, k = inp[:6]
                nxt = 6
                cf_k = None
                if faulted:
                    cf_k, nxt = inp[nxt], nxt + 1
                po_k = inp[nxt] if poisoned else None
                if sharded_store:
                    xs, ys = self._gather_sharded(dx, dy, cid, ix)
                else:
                    # local-step blocks gather [C, E_b, B] index arrays —
                    # broadcast the id column across the extra axes
                    cidx = cid.reshape(cid.shape + (1,) * (ix.ndim - 1))
                    xs = dx[cidx, ix]
                    ys = dy[cidx, ix]
                if dyn:
                    w2, g, losses, thr, _, n_ok, ast, h2 = round_fn(
                        w, v, xs, ys, sw_k, cw_k, inv_k, k, h, cid,
                        noise=inp[-1] if noisy else None,
                        cf=cf_k, poison=po_k)
                    return (w2, g, h2), (losses, thr, n_ok, ast)
                w2, g, losses, thr, _, n_ok, ast = round_fn(
                    w, v, xs, ys, sw_k, cw_k, inv_k, k,
                    noise=inp[-1] if noisy else None,
                    cf=cf_k, poison=po_k)
                return (w2, g), (losses, thr, n_ok, ast)

            xss = ((cids, idxs, sw, cw, inv, ks)
                   + ((cf,) if faulted else ())
                   + ((po,) if poisoned else ()) + rest)
            if dyn:
                (w2, v2, h2), (losses, thrs, n_oks, asts) = jax.lax.scan(
                    body, (w, v, h), xss)
                return w2, v2, h2, losses, thrs, n_oks, asts
            (w2, v2), (losses, thrs, n_oks, asts) = jax.lax.scan(
                body, (w, v), xss)
            return w2, v2, losses, thrs, n_oks, asts

        return impl

    def _fault_entry(self, kind: str, noisy: bool,
                     poisoned: bool = False) -> Callable:
        """Lazily built jit entry points for rounds with fault operands:
        per-round corrupt steps take an extra [C] `cf` (plus a [C, R, L]
        `poison` stack when an additive attack is active — poisoned rounds
        always carry both, ones/zeros-filled defaults being exact no-ops);
        block fault steps take [K, C] `fw` + `cf` stacks and optionally a
        [K, C, R, L] poison stack (wired by _make_block_impl). Cached per
        (kind, noisy, poisoned) so fault runs stay on the same trace-count
        ladder as fault-free ones, one extra family per mode used."""
        key = (kind, noisy, poisoned)
        fn = self._fault_steps.get(key)
        if fn is not None:
            return fn
        shared = kind.endswith("shared")
        if self.mesh is None:
            round_fn = self._round_shared if shared else self._round_multi
        else:
            round_fn = (self._round_shared_sharded if shared
                        else self._round_multi_sharded)
        if kind.startswith("blk"):
            impl = self._make_block_impl(round_fn, noisy=noisy, faulted=True,
                                         poisoned=poisoned)
        elif poisoned and noisy:
            def impl(w, v, xs, ys, sw, cw, inv, k, cf, po, noise,
                     _fn=round_fn):
                self.n_traces += 1
                return _fn(w, v, xs, ys, sw, cw, inv, k, noise=noise, cf=cf,
                           poison=po)
        elif poisoned:
            def impl(w, v, xs, ys, sw, cw, inv, k, cf, po, _fn=round_fn):
                self.n_traces += 1
                return _fn(w, v, xs, ys, sw, cw, inv, k, cf=cf, poison=po)
        elif noisy:
            def impl(w, v, xs, ys, sw, cw, inv, k, cf, noise, _fn=round_fn):
                self.n_traces += 1
                return _fn(w, v, xs, ys, sw, cw, inv, k, noise=noise, cf=cf)
        else:
            def impl(w, v, xs, ys, sw, cw, inv, k, cf, _fn=round_fn):
                self.n_traces += 1
                return _fn(w, v, xs, ys, sw, cw, inv, k, cf=cf)
        fn = jax.jit(impl, donate_argnums=self._donate_args)
        self._fault_steps[key] = fn
        return fn

    def _gather_sharded(self, dx, dy, cid, ix):
        """Batch gather from a data-sharded cohort store: each shard fancy-
        indexes its OWN [rows_per_shard, N_max, ...] block with its shard-
        local ids/indices — no collective, and the outputs come back
        sharded P("data") along the client axis, exactly the layout the
        sharded round bodies' in_specs expect."""
        def gather(d, e, c, i):
            cx = c.reshape(c.shape + (1,) * (i.ndim - 1))
            return d[cx, i], e[cx, i]
        return shard_map(gather, mesh=self.mesh,
                         in_specs=(P("data"), P("data"), P("data"),
                                   P("data")),
                         out_specs=(P("data"), P("data")))(dx, dy, cid, ix)

    def _stream_entry(self, shared: bool, noisy: bool,
                      faulted: bool = False,
                      poisoned: bool = False) -> Callable:
        """Lazily built jit entries for blocks over a SHARDED cohort store
        (streamed fleet path on a mesh): the same block scaffold around the
        same sharded round bodies, with the store gather swapped for the
        shard-local one. Cached beside the fault entries so streamed runs
        pay one extra trace family per mode used, same ladder as before."""
        key = ("stream", shared, noisy, faulted, poisoned)
        fn = self._fault_steps.get(key)
        if fn is None:
            round_fn = (self._round_shared_sharded if shared
                        else self._round_multi_sharded)
            impl = self._make_block_impl(round_fn, noisy=noisy,
                                         faulted=faulted, poisoned=poisoned,
                                         sharded_store=True)
            fn = jax.jit(impl, donate_argnums=self._donate_args)
            self._fault_steps[key] = fn
        return fn

    def _dyn_entry(self, kind: str, noisy: bool, faulted: bool = False,
                   poisoned: bool = False) -> Callable:
        """Lazily built jit entries for the FedDyn (stateful) rounds: the
        same operand order as the plain/fault entries with the state pair
        ``(h, cid)`` appended after k, then the optional cf/poison/noise
        operands. Cached beside the fault entries per (kind, noisy,
        faulted, poisoned) so FedDyn runs stay on the one-extra-family-
        per-mode trace ladder. The state buffer is NOT donated: the
        trainer keeps ownership so a failed dispatch can't strand it."""
        key = ("dyn", kind, noisy, faulted, poisoned)
        fn = self._fault_steps.get(key)
        if fn is not None:
            return fn
        shared = kind.endswith("shared")
        if self.mesh is None:
            round_fn = (self._round_shared_dyn if shared
                        else self._round_multi_dyn)
        else:
            round_fn = (self._round_shared_dyn_sharded if shared
                        else self._round_multi_dyn_sharded)
        if kind.startswith("blk"):
            impl = self._make_block_impl(round_fn, noisy=noisy,
                                         faulted=faulted, poisoned=poisoned,
                                         dyn=True)
        else:
            def impl(w, v, xs, ys, sw, cw, inv, k, h, cid, *rest,
                     _fn=round_fn):
                self.n_traces += 1
                i = 0
                cf = po = noise = None
                if faulted:
                    cf, i = rest[i], i + 1
                if poisoned:
                    po, i = rest[i], i + 1
                if noisy:
                    noise = rest[i]
                return _fn(w, v, xs, ys, sw, cw, inv, k, h, cid,
                           noise=noise, cf=cf, poison=po)
        fn = jax.jit(impl, donate_argnums=self._donate_args)
        self._fault_steps[key] = fn
        return fn

    # -- sharded bodies: client axis over the mesh data axis ----------------
    #
    # Threshold and mask are computed replicated (cheap, deterministic —
    # every device derives the identical mask from the replicated (w, v)),
    # the per-client gradient scan runs on each shard's local clients, and
    # the shards meet in exactly ONE collective: a psum of the weighted
    # per-shard gradient sums. The FedSGD update then runs replicated so
    # (w, v) never need resharding between rounds.

    @staticmethod
    def _guarded_partial(losses, grads, cw, cf, poison=None):
        """Shard-local half of the non-finite guard + the round's single
        collective. Corruption factors (if any) scale the local gradients
        (additive poison joins after, like the single-device tail), the
        isfinite flags zero the weight of any client whose summed
        gradient went non-finite, and ONE tuple psum combines the weighted
        partial gradient sums with the [2] (weighted, surviving) counts —
        the per-round collective count stays at one."""
        if cf is not None:
            grads = grads * cf.astype(jnp.float32)[:, None, None]
        if poison is not None:
            grads = grads + poison.astype(jnp.float32)
        fin = jnp.isfinite(grads).all(axis=(1, 2)).astype(jnp.float32)
        cwe = cw * fin                       # exact: fin is 0.0/1.0
        gsum = ops.packed_weighted_grad_sum(grads, cwe)
        cnt = jnp.stack([cw.sum(), cwe.sum()])
        gsum, cnt = jax.lax.psum((gsum, cnt), "data")
        return losses, gsum, cnt

    @staticmethod
    def _robust_partial(losses, grads, cw, cf, poison=None):
        """Shard-local half of the ROBUST sharded round: rank- and
        distance-based reducers need every client's gradient, not a
        partial sum, so the round's single collective becomes one tuple
        `all_gather` of the (post-fault, quarantine-weighted) local stacks
        along the client axis — replacing the mean path's psum, still
        exactly one collective per round. Tiled gathering over the evenly
        sharded axis reconstructs the single-device [C_b, R, L] stack in
        original client order, and the reducers are bucket-capacity
        invariant, so the sharded robust trajectory is bitwise identical
        to the unsharded one (stronger than the mean path, whose psum
        reassociates the sum — DESIGN.md §11)."""
        if cf is not None:
            grads = grads * cf.astype(jnp.float32)[:, None, None]
        if poison is not None:
            grads = grads + poison.astype(jnp.float32)
        fin = jnp.isfinite(grads).all(axis=(1, 2)).astype(jnp.float32)
        cwe = cw * fin                       # exact: fin is 0.0/1.0
        ga, cwea = jax.lax.all_gather((grads, cwe), "data", axis=0,
                                      tiled=True)
        return losses, ga, cwea

    def _robust_tail(self, w, v, grads, cw_eff, noise):
        """Replicated robust tail: reduce the gathered full stack with the
        engine's aggregator and apply the same FMA-fenced inv=1.0 update
        as the single-device robust branch (bitwise-identical inputs ->
        bitwise-identical round)."""
        ghat, ast = self.aggregator.reduce(grads, cw_eff)
        n_ok = cw_eff.sum()
        w2, g, step = ops.packed_apply_mean_update(
            w, ghat, jnp.float32(1.0), self.eta, noise=noise)
        alive = n_ok > 0.0
        w2 = jnp.where(alive, w2, w)
        g = jnp.where(alive, g, v)
        return w2, g, step, n_ok.astype(jnp.int32), ast

    def _guarded_tail(self, w, v, gsum, cnt, inv, noise):
        """Replicated guard tail for the sharded bodies: renormalize the
        mean over the cross-shard survivor count (host `inv` passes through
        value-identically when every weighted client survived — the same
        contract as ops.packed_client_quarantine), apply the update, and
        carry (w, v) unchanged when no client survived."""
        n_w, n_ok = cnt[0], cnt[1]
        inv_eff = jnp.where(
            n_ok == n_w, jnp.asarray(inv, jnp.float32),
            jnp.where(n_ok > 0.0, 1.0 / jnp.maximum(n_ok, 1.0), 0.0))
        w2, g, step = ops.packed_apply_mean_update(w, gsum, inv_eff,
                                                   self.eta, noise=noise)
        alive = n_ok > 0.0
        w2 = jnp.where(alive, w2, w)
        g = jnp.where(alive, g, v)
        return w2, g, step, n_ok.astype(jnp.int32)

    def _round_shared_sharded(self, w, v, xs, ys, sw, cw, inv, k, noise=None,
                              cf=None, poison=None):
        """Mesh variant of _round_shared: threshold / mask / FedSGD update
        replicated OUTSIDE the shard_map region (the shard_map replication
        checker has no rule for the `while` ops inside the threshold
        search and the FMA fence), per-shard gradient scan + the round's
        single collective inside (the mean path's psum, or the robust
        path's all_gather when an aggregator is set — the reducers need
        the full client stack). Traced by both the per-round jit and the
        block scan, like its single-device sibling. `noise` (replicated)
        joins the replicated update tail — the collective count is
        unchanged. `cf` / `poison` (per-client fault operands) shard with
        the client axis."""
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, k)
        _, mask = ops.packed_importance_mask(w, v, self.prunable, thr,
                                             impl=self.kernel_impl)
        pruned = w * mask

        robust = self.aggregator is not None
        partial = self._robust_partial if robust else self._guarded_partial

        def body(pruned, mask, xs, ys, sw, cw, *extra):
            losses, grads = self._client_grads_shared(pruned, mask, xs, ys,
                                                      sw)
            return partial(losses, grads, cw,
                           extra[0] if cf is not None else None,
                           extra[-1] if poison is not None else None)

        specs = (P(), P(), P("data"), P("data"), P("data"), P("data"))
        args = (pruned, mask, xs, ys, sw, cw)
        if cf is not None:
            specs, args = specs + (P("data"),), args + (cf,)
        if poison is not None:
            specs, args = specs + (P("data"),), args + (poison,)
        # the robust tail reduces the all_gather'd full stack identically
        # on every shard — genuinely replicated, but the static replication
        # checker has no rule for gather-then-reduce (unlike psum), so the
        # check is disabled on that path only
        losses, a, b = shard_map(
            body, mesh=self.mesh, in_specs=specs,
            out_specs=(P("data"), P(), P()), check_rep=not robust)(*args)
        if robust:
            w2, g, step, n_ok, ast = self._robust_tail(w, v, a, b, noise)
        else:
            w2, g, step, n_ok = self._guarded_tail(w, v, a, b, inv, noise)
            ast = jnp.int32(0)
        return w2, g, losses, thr, step, n_ok, ast

    def _round_multi_sharded(self, w, v, xs, ys, sw, cw, inv, ks, noise=None,
                             cf=None, poison=None):
        """Mesh variant of _round_multi (see _round_shared_sharded)."""
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, ks)      # [C]

        robust = self.aggregator is not None
        partial = self._robust_partial if robust else self._guarded_partial

        def body(w_, v_, pr, thr_, xs_, ys_, sw_, cw_, *extra):
            # per-shard masks from the local thresholds: the batched
            # kernel reads the replicated (w, v) once, local masks only
            _, masks = ops.packed_importance_masks(w_, v_, pr, thr_,
                                                   impl=self.kernel_impl)
            losses, grads = self._client_grads_multi(w_, masks, xs_, ys_,
                                                     sw_)
            return partial(losses, grads, cw_,
                           extra[0] if cf is not None else None,
                           extra[-1] if poison is not None else None)

        specs = (P(), P(), P(), P("data"), P("data"), P("data"),
                 P("data"), P("data"))
        args = (w, v, self.prunable, thr, xs, ys, sw, cw)
        if cf is not None:
            specs, args = specs + (P("data"),), args + (cf,)
        if poison is not None:
            specs, args = specs + (P("data"),), args + (poison,)
        # see _round_shared_sharded: robust outputs are replicated by
        # construction (gather-then-reduce), invisible to the static check
        losses, a, b = shard_map(
            body, mesh=self.mesh, in_specs=specs,
            out_specs=(P("data"), P(), P()), check_rep=not robust)(*args)
        if robust:
            w2, g, step, n_ok, ast = self._robust_tail(w, v, a, b, noise)
        else:
            w2, g, step, n_ok = self._guarded_tail(w, v, a, b, inv, noise)
            ast = jnp.int32(0)
        return w2, g, losses, thr, step, n_ok, ast

    def _shared_sharded_impl(self, w, v, xs, ys, sw, cw, inv, k):
        self.n_traces += 1
        return self._round_shared_sharded(w, v, xs, ys, sw, cw, inv, k)

    def _multi_sharded_impl(self, w, v, xs, ys, sw, cw, inv, ks):
        self.n_traces += 1
        return self._round_multi_sharded(w, v, xs, ys, sw, cw, inv, ks)

    # -- public API ---------------------------------------------------------

    def bucket_size(self, n_clients: int) -> int:
        """Padded client-axis size for a round selecting `n_clients`:
        shards * next_pow2(ceil(n_clients / shards)), capped at the client
        population when known (padding clients cost real gradient FLOPs, so
        full participation must not pad past the roster). A training run
        compiles at most log2(C_max)+1 step traces per lambda family."""
        return bucket_capacity(n_clients, shards=self.shards,
                               bucket=self.bucket,
                               max_clients=self.max_clients)

    def init_buffers(self, params: PyTree) -> tuple[jnp.ndarray, jnp.ndarray]:
        w = self.pack.pack(params)
        return w, jnp.zeros_like(w)

    def round_step(self, w, v, xs, ys, lams, sample_weights=None,
                   noise=None, upload_weights=None, corrupt=None,
                   poison=None, h=None, client_ids=None):
        """One full round. xs: [C, B, ...], ys: [C, B], lams: [C] host-side
        pruning ratios for the selected clients; sample_weights: optional
        [C, B] 0/1 per-sample weights (ragged clients padded to B);
        noise: optional packed [R, L] aggregation-channel noise (zero on
        padding lanes) added to the mean gradient before the update — the
        noisy-uplink axis (wireless/channel.GaussianAggregateNoise).
        upload_weights: optional [C] 0/1 floats — 0 marks a client whose
        upload never arrived (dropout/straggler draw, core/faults.py); the
        client rides the padding-client path (weight 0) and the host mean
        scalar renormalizes over the survivors, so NO new trace is paid.
        corrupt: optional [C] per-client gradient factors (1.0 = clean,
        NaN = poisoned) — a traced operand, routed through the lazily
        built fault entry points.
        poison: optional [C, R, L] additive upload poison (zeros = clean
        client) — the GaussianPoison byzantine axis; it rides the same
        fault entries (a poisoned round always carries a `cf` operand
        too, ones-filled when no multiplicative fault fired).
        With a multi-step LocalScheme, xs/ys/sample_weights carry a step
        axis after the client axis — xs: [C, E, B, ...] with E =
        local_scheme.steps — padded here to the static pow2 step bucket
        (padded steps replicate the last real batch and are exact no-ops).
        FedDyn additionally requires `h` (the [C_all, R, L] correction
        state) and `client_ids` ([C] ids indexing its rows); the updated
        state lands in `last_h` (device array, never synced).
        Returns (w', v', losses [C], threshold, step) — all device arrays;
        nothing is synced to host (`last_n_ok` additionally holds the
        round's lazy survivor count). `step` is the applied update eta*v'
        (kept as an output so the update's multiply can never be
        FMA-contracted — the bit-for-bit contract with the reference
        trainer depends on it)."""
        lams = np.atleast_1d(np.asarray(lams, np.float64))
        if np.any((lams < 0.0) | (lams >= 1.0)):
            raise ValueError(f"lambda must be in [0,1), got {lams}")
        n_clients = int(xs.shape[0])
        if lams.shape[0] != n_clients:
            raise ValueError(
                f"{lams.shape[0]} lambdas for {n_clients} client batches")
        ks = np.floor(lams * self.pack.n_prunable).astype(np.int32)

        # pad the step axis to its static pow2 bucket first: padded steps
        # replicate the last real step's batch (no RNG consumed) and are
        # gated off by the validity vector inside the step scan
        ls = self.local_scheme
        if ls is not None:
            if int(xs.shape[1]) != ls.steps:
                raise ValueError(
                    f"expected {ls.steps} local-step batches per client, "
                    f"got {xs.shape[1]}")
            epad = ls.steps_bucket - ls.steps
            if epad:
                def pad_steps(a):
                    a = jnp.asarray(a)
                    reps = jnp.broadcast_to(
                        a[:, -1:], (a.shape[0], epad) + a.shape[2:])
                    return jnp.concatenate([a, reps], axis=1)
                xs, ys = pad_steps(xs), pad_steps(ys)
                if sample_weights is not None:
                    sample_weights = pad_steps(
                        jnp.asarray(sample_weights, jnp.float32))

        # pad the client axis to the bucket; padding clients replicate the
        # last real batch and carry weight 0, so they never touch the update
        c_b = self.bucket_size(n_clients)
        self.buckets_used.add(c_b)
        pad = c_b - n_clients
        if sample_weights is None:
            key = (c_b,) + tuple(int(s) for s in ys.shape[1:])
            sw = self._sw_cache.get(key)
            if sw is None:
                sw = self._sw_cache[key] = jnp.ones(key, jnp.float32)
        else:
            sw = jnp.asarray(sample_weights, jnp.float32)
        if pad:
            def tile(a):
                return jnp.concatenate(
                    [a, jnp.broadcast_to(a[-1:], (pad,) + a.shape[1:])])
            xs, ys = tile(xs), tile(ys)
            if sample_weights is not None:
                sw = tile(sw)
        if upload_weights is None:
            cw = self._cw_cache.get((c_b, n_clients))
            if cw is None:
                cw_host = np.zeros(c_b, np.float32)
                cw_host[:n_clients] = 1.0
                cw = self._cw_cache[(c_b, n_clients)] = jnp.asarray(cw_host)
            # 1/C on host, like the reference server_step's 1/len(grads)
            inv = np.float32(1.0 / n_clients)
        else:
            # fault draw folded into the same 0/1 weight operand padding
            # clients already use — identical trace, new operand values;
            # the mean renormalizes over the survivors exactly as the
            # reference server_step's 1/len(surviving grads) does
            uw = np.asarray(upload_weights, np.float32)
            if uw.shape != (n_clients,):
                raise ValueError(
                    f"upload_weights shape {uw.shape} != ({n_clients},)")
            cw_host = np.zeros(c_b, np.float32)
            cw_host[:n_clients] = uw
            cw = jnp.asarray(cw_host)
            surv = float(np.asarray(uw, np.float64).sum())
            inv = np.float32(1.0 / surv) if surv > 0 else np.float32(0.0)
        po = None
        if poison is not None:
            po = jnp.asarray(poison, jnp.float32)
            if po.shape[0] != n_clients:
                raise ValueError(
                    f"poison leading dim {po.shape[0]} != {n_clients}")
            if pad:
                # padding clients stay clean: additive identity is 0
                po = jnp.concatenate(
                    [po, jnp.zeros((pad,) + po.shape[1:], jnp.float32)])
        cf = None
        if corrupt is not None or po is not None:
            cf_host = np.ones(c_b, np.float32)   # padding clients clean
            if corrupt is not None:
                cf_host[:n_clients] = np.asarray(corrupt, np.float32)
            cf = jnp.asarray(cf_host)
        fargs = () if cf is None else (
            (cf,) + (() if po is None else (po,)))

        dyn = ls is not None and ls.stateful
        if dyn:
            if h is None or client_ids is None:
                raise ValueError(
                    "feddyn round_step requires the correction state h and "
                    "the selected client_ids")
            cid = np.asarray(client_ids, np.int32)
            if cid.shape != (n_clients,):
                raise ValueError(
                    f"client_ids shape {cid.shape} != ({n_clients},)")
            if pad:
                # padding clients replicate the last real id; their state
                # scatter contribution is exact +0.0 (weight 0), a no-op
                cid = np.concatenate([cid, np.full(pad, cid[-1], np.int32)])
            dargs = (h, jnp.asarray(cid))

        nz = () if noise is None else (jnp.asarray(noise),)
        if np.all(ks == ks[0]):
            k_dev = jnp.asarray(ks[0], jnp.int32)
            if dyn:
                out = self._dyn_entry("shared", noise is not None,
                                      cf is not None, po is not None)(
                    w, v, xs, ys, sw, cw, inv, k_dev, *dargs, *fargs, *nz)
            elif cf is not None:
                out = self._fault_entry("shared", noise is not None,
                                        po is not None)(
                    w, v, xs, ys, sw, cw, inv, k_dev, *fargs, *nz)
            else:
                out = (self._step_shared(w, v, xs, ys, sw, cw, inv, k_dev)
                       if noise is None else
                       self._step_shared_nz(w, v, xs, ys, sw, cw, inv, k_dev,
                                            *nz))
        else:
            ks_b = np.concatenate(
                [ks, np.full(pad, ks[-1], np.int32)]) if pad else ks
            ks_dev = jnp.asarray(ks_b)
            if dyn:
                out = self._dyn_entry("multi", noise is not None,
                                      cf is not None, po is not None)(
                    w, v, xs, ys, sw, cw, inv, ks_dev, *dargs, *fargs, *nz)
            elif cf is not None:
                out = self._fault_entry("multi", noise is not None,
                                        po is not None)(
                    w, v, xs, ys, sw, cw, inv, ks_dev, *fargs, *nz)
            else:
                out = (self._step_multi(w, v, xs, ys, sw, cw, inv, ks_dev)
                       if noise is None else
                       self._step_multi_nz(w, v, xs, ys, sw, cw, inv, ks_dev,
                                           *nz))
        if dyn:
            w2, g, losses, thr, step, n_ok, ast, h2 = out
            self.last_h = h2
        else:
            w2, g, losses, thr, step, n_ok, ast = out
        self.last_n_ok = n_ok
        self.last_agg_stat = ast
        if pad:
            losses = losses[:n_clients]
            if thr.ndim:                      # per-client thresholds
                thr = thr[:n_clients]
        return w2, g, losses, thr, step

    def block_step(self, w, v, store, cids, idxs, lams, counts,
                   sample_weights=None, noises=None, upload_weights=None,
                   corrupt=None, poisons=None, h=None):
        """K rounds in ONE jitted dispatch (`lax.scan` over the round axis).

        store : ClientStore — device-resident [C_all, N_max, ...] data.
        cids  : [K, C] int  — selected client ids per round in selected
            order; rounds with fewer than C clients are right-padded by
            replicating their last real id (exactly the per-round path's
            padding-client convention).
        idxs  : [K, C, B] int — host-drawn sample indices into each
            client's store rows. Drawing them from the same numpy RNG
            stream as `_sample_batch` keeps the batch sequence — and the
            bit-for-bit contract with the reference loop — intact.
        lams  : [K, C] float — pruning ratios, padded like cids.
        counts: [K] int     — real selected count per round.
        sample_weights : [K, C, B] 0/1 weights or None (ragged clients
            padded to B carry 0 on their repeat samples).
        noises : [K, R, L] per-round packed aggregation noise or None —
            one stack per block dispatch (never a per-round upload), each
            round consuming its own slice inside the scan.
        upload_weights : [K, C] 0/1 floats or None — host-drawn fault
            masks (0 = the upload never arrived); they join the stacked
            schedule operands exactly like cids/ks — ONE upload per block,
            the zero-per-round-H2D property is preserved — and multiply
            into the counts-derived client weights on device.
        corrupt : [K, C] per-client gradient factors or None (1.0 =
            clean). Any fault operand routes the block through the
            lazily built fault entry, which always consumes BOTH [K, C]
            stacks (ones-filled defaults are exact no-ops), so a fault run
            uses one entry per (shape bucket) regardless of which kinds
            fired.
        poisons : [K, C, R, L] additive upload poison or None (zeros =
            clean) — the byzantine GaussianPoison axis. The one block
            operand whose size scales with the model; still ONE upload per
            block, never per round, so the zero-per-round-H2D property
            holds.

        Returns (w', v', losses [K, C_b], thresholds [K] or [K, C_b]) —
        all device arrays, nothing synced; `losses[k, counts[k]:]` belongs
        to padding clients (callers slice). Batch DATA never crosses
        host->device here — only O(K*C*B) int32 index/schedule arrays do.

        The client axis buckets exactly like `round_step` (all rounds in a
        block must share one bucket — the trainer groups rounds so this
        holds); K is NOT padded — padding rounds would cost full gradient
        FLOPs — so callers keep K on a pow2 ladder by decomposition, and
        `k_buckets_used` records the ladder for the trace-bound tests.
        """
        lams = np.asarray(lams, np.float64)
        if np.any((lams < 0.0) | (lams >= 1.0)):
            raise ValueError(f"lambda must be in [0,1), got {lams}")
        # multi-step blocks draw [K, C, E, B] index arrays; the step axis
        # pads to the static pow2 bucket exactly like round_step's batches
        # (replicate the last real step — no RNG consumed, gated no-ops)
        ls = self.local_scheme
        idxs = np.asarray(idxs, np.int32)
        if ls is not None:
            if idxs.ndim != 4 or int(idxs.shape[2]) != ls.steps:
                raise ValueError(
                    f"expected [K, C, {ls.steps}, B] local-step indices, "
                    f"got shape {idxs.shape}")
            epad = ls.steps_bucket - ls.steps
            if epad:
                idxs = np.concatenate(
                    [idxs, np.repeat(idxs[:, :, -1:], epad, axis=2)],
                    axis=2)
                if sample_weights is not None:
                    sws = np.asarray(sample_weights, np.float32)
                    sample_weights = np.concatenate(
                        [sws, np.repeat(sws[:, :, -1:], epad, axis=2)],
                        axis=2)
            n_rounds, c_max = idxs.shape[:2]
            batch = int(idxs.shape[3])
        else:
            if idxs.ndim != 3:
                raise ValueError(
                    f"expected [K, C, B] indices, got shape {idxs.shape}")
            n_rounds, c_max, batch = idxs.shape
        counts = np.asarray(counts, np.int64)
        if counts.shape != (n_rounds,) or cids.shape != (n_rounds, c_max) \
                or lams.shape != (n_rounds, c_max):
            raise ValueError("inconsistent block array shapes")
        if int(counts.max()) > c_max or int(counts.min()) < 1:
            raise ValueError(f"counts {counts} outside [1, {c_max}]")
        ks = np.floor(lams * self.pack.n_prunable).astype(np.int32)

        c_b = self.bucket_size(int(counts.max()))
        if self.bucket_size(int(counts.min())) != c_b:
            raise ValueError(
                "rounds in one block must share a client-axis bucket "
                f"(got counts {counts} -> buckets "
                f"{sorted({self.bucket_size(int(c)) for c in counts})})")
        self.buckets_used.add(c_b)
        self.k_buckets_used.add(n_rounds)
        pad = c_b - c_max

        def pad_cols(a):
            return np.concatenate(
                [a, np.repeat(a[:, -1:], pad, axis=1)], axis=1) if pad else a

        cids = pad_cols(np.asarray(cids, np.int32))
        idxs = pad_cols(idxs)
        ks = pad_cols(ks)
        if sample_weights is None:
            key = (("blk", n_rounds, c_b, batch) if ls is None else
                   ("blk", n_rounds, c_b, ls.steps_bucket, batch))
            sw = self._sw_cache.get(key)
            if sw is None:
                sw = self._sw_cache[key] = jnp.ones(key[1:], jnp.float32)
        else:
            sw = jnp.asarray(pad_cols(
                np.asarray(sample_weights, np.float32)))
        po = None
        if poisons is not None:
            po = np.asarray(poisons, np.float32)
            if po.shape[:2] != (n_rounds, c_max):
                raise ValueError(
                    f"poisons leading dims {po.shape[:2]} != "
                    f"({n_rounds}, {c_max})")
            if pad:
                # padding clients stay clean: additive identity is 0
                po = np.concatenate(
                    [po, np.zeros((n_rounds, pad) + po.shape[2:],
                                  np.float32)], axis=1)
        faulted = (upload_weights is not None or corrupt is not None
                   or po is not None)
        if faulted:
            # per-round survivor counts drive the host mean scalars; the
            # float64 1/n -> float32 cast gives the identical value to the
            # reference server_step's np.float32(1.0 / n) (double rounding
            # is safe: p=53 >= 2*24+2)
            uw = (np.ones((n_rounds, c_max), np.float32)
                  if upload_weights is None
                  else np.asarray(upload_weights, np.float32))
            cfa = (np.ones((n_rounds, c_max), np.float32)
                   if corrupt is None else np.asarray(corrupt, np.float32))
            if uw.shape != (n_rounds, c_max) or cfa.shape != (n_rounds, c_max):
                raise ValueError("fault operand shapes must be [K, C]")
            col = np.arange(c_max)[None, :]
            surv = (uw.astype(np.float64) * (col < counts[:, None])).sum(1)
            inv_host = np.where(surv > 0, 1.0 / np.maximum(surv, 1.0), 0.0)
        else:
            # per-round 1/C on host, like the reference server_step's
            # 1/len(grads); the 0/1 client weights are derived from
            # `counts` on device inside the block impl (no per-block
            # [K, C_b] upload)
            inv_host = 1.0 / counts
        inv = jnp.asarray(inv_host.astype(np.float32))
        counts_dev = jnp.asarray(counts.astype(np.int32))

        def pad_ones(a):
            # padding clients carry weight 0 either way; keep their fault
            # operands clean (1.0) so a poisoned last real client can't
            # replicate NaNs into padding lanes
            return np.concatenate(
                [a, np.ones((n_rounds, pad), np.float32)],
                axis=1) if pad else a

        shared = bool((ks == ks[:, :1]).all())
        nz = () if noises is None else (jnp.asarray(noises),)
        ks_dev = jnp.asarray(ks[:, 0]) if shared else jnp.asarray(ks)
        # a data-sharded cohort store (streamed fleet path) swaps the
        # replicated-store gather for the shard-local one; the round bodies
        # and operand layout are otherwise identical
        streamed = self.mesh is not None and bool(
            getattr(store, "sharded", False))
        dyn = ls is not None and ls.stateful
        if dyn:
            if h is None:
                raise ValueError(
                    "feddyn block_step requires the correction state h")
            if streamed:
                raise ValueError(
                    "feddyn over a data-sharded cohort store is not "
                    "supported: run with shards=1 (streamed cohorts stay "
                    "available) or client_store='replicated'")
            fn = self._dyn_entry("blk_shared" if shared else "blk_multi",
                                 noises is not None, faulted,
                                 po is not None)
            out = fn(w, v, h, store.x, store.y, jnp.asarray(cids),
                     jnp.asarray(idxs), sw, counts_dev, inv, ks_dev,
                     *((jnp.asarray(pad_ones(uw)),
                        jnp.asarray(pad_ones(cfa))) if faulted else ()),
                     *(() if po is None else (jnp.asarray(po),)), *nz)
            w2, v2, h2, losses, thrs, n_oks, asts = out
            self.last_h = h2
            self.last_n_ok = n_oks
            self.last_agg_stat = asts
            return w2, v2, losses, thrs
        if faulted:
            fn = (self._stream_entry(shared, noises is not None, True,
                                     po is not None) if streamed
                  else self._fault_entry(
                      "blk_shared" if shared else "blk_multi",
                      noises is not None, po is not None))
            out = fn(w, v, store.x, store.y, jnp.asarray(cids),
                     jnp.asarray(idxs), sw, counts_dev, inv, ks_dev,
                     jnp.asarray(pad_ones(uw)), jnp.asarray(pad_ones(cfa)),
                     *(() if po is None else (jnp.asarray(po),)), *nz)
        elif streamed:
            fn = self._stream_entry(shared, noises is not None)
            out = fn(w, v, store.x, store.y, jnp.asarray(cids),
                     jnp.asarray(idxs), sw, counts_dev, inv, ks_dev, *nz)
        elif shared:
            fn = self._blk_shared if noises is None else self._blk_shared_nz
            out = fn(w, v, store.x, store.y, jnp.asarray(cids),
                     jnp.asarray(idxs), sw, counts_dev, inv, ks_dev, *nz)
        else:
            fn = self._blk_multi if noises is None else self._blk_multi_nz
            out = fn(w, v, store.x, store.y, jnp.asarray(cids),
                     jnp.asarray(idxs), sw, counts_dev, inv, ks_dev, *nz)
        w2, v2, losses, thrs, n_oks, asts = out
        self.last_n_ok = n_oks
        self.last_agg_stat = asts
        return w2, v2, losses, thrs
