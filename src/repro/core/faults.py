"""Client fault models: per-(seed, round, client) failure draws (DESIGN.md §10).

The paper's system model assumes every scheduled client uploads a finite
gradient within the round deadline — the exact assumption real FEEL
deployments violate (stragglers/dropouts are the dominant failure mode in
the FEEL design-issues survey; Wu et al. motivate corrupted uploads over
deep-fade links). A `FaultModel` injects those failures as a first-class,
registry-resolved axis (repro.api.registry FAULT_MODELS, spec field
`wireless.fault_model`):

  * dropout   — the client never uploads (weight 0 in the aggregate);
  * straggler — the upload exceeds a delay deadline derived from the
    wireless delay model (eqs. 10-11): client n faults when its drawn
    slowdown times its scheduled per-client delay exceeds ``tolerance *
    deadline``, where the deadline is the round's scheduled straggler
    latency (``max_n a_n (tau_n + tau^_n)``, eq. 12) — so exclusion
    couples to the same T constraint the paper's schedule optimizes;
  * corrupt   — the upload arrives but is scaled or NaN-poisoned
    (deep-fade / decode-failure model).

Draw protocol
-------------
``draw(round_index, n_clients, selected, ...)`` returns a `FaultDraw` for
the round's selected clients. Every model draws a POPULATION-sized array
from an rng keyed ONLY by ``(seed, round, kind)`` and then indexes it with
the selected ids — so a client's fate at round s is a pure function of
(seed, s, client id), invariant to how many clients are selected, to
dispatch grouping (rounds_per_dispatch = 1 vs K), and to checkpoint
resume. Both execution backends consume the identical draw (the trainer
attaches it to the round's schedule info), which is what keeps fault runs
bitwise packed-vs-reference (tests/test_faults.py).

Graceful degradation — how draws are consumed — lives in the engine:
faulted clients get weight 0 in the weighted aggregate, the mean
renormalizes by the surviving count, non-finite (corrupt) uploads are
quarantined by the engine's always-on isfinite guard, and an all-fault
round skips the update entirely (core/round_engine.py, kernels/ops.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Distinct rng streams per fault kind so a mixed model's dropout draw never
# correlates with its corruption draw at the same (seed, round).
_DROPOUT, _STRAGGLER, _CORRUPT = 1, 2, 3


def _round_rng(seed: int, round_index: int, kind: int) -> np.random.Generator:
    """The (seed, round, kind)-keyed generator — same keying discipline as
    wireless/channel.GaussianAggregateNoise: no shared stream position, so
    draws are invariant to dispatch grouping and resume."""
    return np.random.default_rng(np.random.SeedSequence(
        [int(seed) & 0xFFFFFFFF, int(round_index), int(kind)]))


@dataclasses.dataclass(frozen=True)
class FaultDraw:
    """One round's fault outcome for the selected clients (selected order).

    upload_ok : [C_sel] bool — False = the upload never arrives (dropout /
        straggler past the deadline); the client gets weight 0 and the
        aggregate renormalizes over the survivors.
    corrupt   : [C_sel] float32 or None — per-client gradient scale factor
        (1.0 = clean; NaN = poisoned). Applied to uploads that DO arrive;
        non-finite results are then caught by the engine's isfinite guard.
    """

    upload_ok: np.ndarray
    corrupt: np.ndarray | None = None

    @property
    def n_faulted(self) -> int:
        return int((~np.asarray(self.upload_ok, bool)).sum())


class FaultModel:
    """Protocol: per-round fault draws over the client population.

    ``delays`` ([C_sel] float, seconds — each selected client's scheduled
    tau_n + tau^_n) and ``deadline`` (the round's scheduled straggler
    latency) come from the wireless bookkeeping the trainer already
    computes; models that don't need them ignore them.
    """

    def draw(self, round_index: int, n_clients: int, selected: np.ndarray,
             *, delays: np.ndarray | None = None,
             deadline: float | None = None) -> FaultDraw:
        raise NotImplementedError

    @staticmethod
    def _all_ok(n_sel: int) -> np.ndarray:
        return np.ones(n_sel, bool)


@dataclasses.dataclass(frozen=True)
class ClientDropout(FaultModel):
    """Each client independently drops its round with probability `rate`."""

    rate: float = 0.1
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"dropout rate must be in [0, 1], got {self.rate}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        u = _round_rng(self.seed, round_index, _DROPOUT).random(n_clients)
        return FaultDraw(upload_ok=u[np.asarray(selected, int)] >= self.rate)


@dataclasses.dataclass(frozen=True)
class StragglerTimeout(FaultModel):
    """Lognormal per-client slowdown against a deadline from the wireless
    delay model: client n misses the round when ``slowdown_n * delay_n >
    tolerance * deadline`` — the deadline being the round's scheduled
    straggler latency (eq. 12's per-round max), so the paper's T constraint
    is exactly the budget stragglers are judged against. With no wireless
    context (delays/deadline not supplied) nobody straggles."""

    tolerance: float = 1.5              # deadline slack factor
    sigma: float = 0.5                  # lognormal(0, sigma) slowdown spread
    seed: int = 0

    def __post_init__(self):
        if self.tolerance <= 0.0:
            raise ValueError(f"tolerance must be > 0, got {self.tolerance}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        sel = np.asarray(selected, int)
        slow = _round_rng(self.seed, round_index,
                          _STRAGGLER).lognormal(0.0, self.sigma,
                                                n_clients)[sel]
        if delays is None or deadline is None or deadline <= 0.0:
            return FaultDraw(upload_ok=self._all_ok(len(sel)))
        eff = np.asarray(delays, np.float64) * slow
        return FaultDraw(upload_ok=eff <= self.tolerance * float(deadline))


@dataclasses.dataclass(frozen=True)
class CorruptUpload(FaultModel):
    """Each arriving upload is independently corrupted with probability
    `rate`: ``mode="nan"`` poisons the gradient (quarantined by the
    engine's isfinite guard), ``mode="scale"`` multiplies it by `scale`
    (a finite deep-fade distortion that DOES reach the aggregate)."""

    rate: float = 0.05
    mode: str = "nan"                   # "nan" | "scale"
    scale: float = 100.0
    seed: int = 0

    def __post_init__(self):
        if self.mode not in ("nan", "scale"):
            raise ValueError(f"unknown corrupt mode {self.mode!r}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"corrupt rate must be in [0, 1], got {self.rate}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        sel = np.asarray(selected, int)
        u = _round_rng(self.seed, round_index, _CORRUPT).random(n_clients)[sel]
        cf = np.ones(len(sel), np.float32)
        cf[u < self.rate] = (np.float32("nan") if self.mode == "nan"
                             else np.float32(self.scale))
        return FaultDraw(upload_ok=self._all_ok(len(sel)), corrupt=cf)


@dataclasses.dataclass(frozen=True)
class MixedFaults(FaultModel):
    """Composition of the three kinds with independent per-kind streams
    (the chaos model scripts/test.sh's chaos-smoke leg runs). A kind is
    active when its knob is set: ``dropout_rate`` / ``corrupt_rate`` > 0,
    ``straggler_tolerance`` not None."""

    dropout_rate: float = 0.0
    corrupt_rate: float = 0.0
    corrupt_mode: str = "nan"
    corrupt_scale: float = 100.0
    straggler_tolerance: float | None = None
    straggler_sigma: float = 0.5
    seed: int = 0

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        sel = np.asarray(selected, int)
        ok = self._all_ok(len(sel))
        corrupt = None
        if self.dropout_rate > 0.0:
            ok &= ClientDropout(self.dropout_rate, self.seed).draw(
                round_index, n_clients, sel).upload_ok
        if self.straggler_tolerance is not None:
            ok &= StragglerTimeout(self.straggler_tolerance,
                                   self.straggler_sigma, self.seed).draw(
                round_index, n_clients, sel, delays=delays,
                deadline=deadline).upload_ok
        if self.corrupt_rate > 0.0:
            corrupt = CorruptUpload(self.corrupt_rate, self.corrupt_mode,
                                    self.corrupt_scale, self.seed).draw(
                round_index, n_clients, sel).corrupt
        return FaultDraw(upload_ok=ok, corrupt=corrupt)
