"""Client fault models: per-(seed, round, client) failure draws (DESIGN.md §10).

The paper's system model assumes every scheduled client uploads a finite
gradient within the round deadline — the exact assumption real FEEL
deployments violate (stragglers/dropouts are the dominant failure mode in
the FEEL design-issues survey; Wu et al. motivate corrupted uploads over
deep-fade links). A `FaultModel` injects those failures as a first-class,
registry-resolved axis (repro.api.registry FAULT_MODELS, spec field
`wireless.fault_model`):

  * dropout   — the client never uploads (weight 0 in the aggregate);
  * straggler — the upload exceeds a delay deadline derived from the
    wireless delay model (eqs. 10-11): client n faults when its drawn
    slowdown times its scheduled per-client delay exceeds ``tolerance *
    deadline``, where the deadline is the round's scheduled straggler
    latency (``max_n a_n (tau_n + tau^_n)``, eq. 12) — so exclusion
    couples to the same T constraint the paper's schedule optimizes;
  * corrupt   — the upload arrives but is scaled or NaN-poisoned
    (deep-fade / decode-failure model).

Adversarial (byzantine) models — PR 7 — reuse the same draw machinery but
model a *deliberate* attacker rather than channel damage, pairing with the
robust aggregators in core/aggregators.py:

  * sign_flip        — byzantine clients upload ``-scale * g`` (gradient
    ascent; rides the multiplicative `corrupt` operand);
  * scaled_malicious — byzantine clients upload ``+scale * g`` (magnitude
    attack, same operand);
  * gaussian_poison  — byzantine clients upload ``g + sigma * z`` with
    z ~ N(0, I) over the packed buffer (additive; carried by the draw's
    lazy ``poison`` callable so clean rounds never materialize a
    model-sized array).

Draw protocol
-------------
``draw(round_index, n_clients, selected, ...)`` returns a `FaultDraw` for
the round's selected clients. Every model draws a POPULATION-sized array
from an rng keyed ONLY by ``(seed, round, kind)`` and then indexes it with
the selected ids — so a client's fate at round s is a pure function of
(seed, s, client id), invariant to how many clients are selected, to
dispatch grouping (rounds_per_dispatch = 1 vs K), and to checkpoint
resume. Both execution backends consume the identical draw (the trainer
attaches it to the round's schedule info), which is what keeps fault runs
bitwise packed-vs-reference (tests/test_faults.py).

Graceful degradation — how draws are consumed — lives in the engine:
faulted clients get weight 0 in the weighted aggregate, the mean
renormalizes by the surviving count, non-finite (corrupt) uploads are
quarantined by the engine's always-on isfinite guard, and an all-fault
round skips the update entirely (core/round_engine.py, kernels/ops.py).
"""
from __future__ import annotations

import dataclasses
import typing

import numpy as np

# Distinct rng streams per fault kind so a mixed model's dropout draw never
# correlates with its corruption draw at the same (seed, round).
_DROPOUT, _STRAGGLER, _CORRUPT, _BYZANTINE = 1, 2, 3, 4


def _round_rng(seed: int, round_index: int, kind: int) -> np.random.Generator:
    """The (seed, round, kind)-keyed generator — same keying discipline as
    wireless/channel.GaussianAggregateNoise: no shared stream position, so
    draws are invariant to dispatch grouping and resume."""
    return np.random.default_rng(np.random.SeedSequence(
        [int(seed) & 0xFFFFFFFF, int(round_index), int(kind)]))


@dataclasses.dataclass(frozen=True)
class FaultDraw:
    """One round's fault outcome for the selected clients (selected order).

    upload_ok : [C_sel] bool — False = the upload never arrives (dropout /
        straggler past the deadline); the client gets weight 0 and the
        aggregate renormalizes over the survivors.
    corrupt   : [C_sel] float32 or None — per-client gradient scale factor
        (1.0 = clean; NaN = poisoned). Applied to uploads that DO arrive;
        non-finite results are then caught by the engine's isfinite guard.
    poison    : callable or None — lazy additive upload poison:
        ``poison(shape, valid) -> float32 [C_sel, *shape]`` with zeros for
        clean clients, drawn per flagged client from an rng keyed
        ``(seed, round, _BYZANTINE, client_id)`` and masked by the packed
        buffer's `valid` lanes (so padding lanes stay exactly 0.0 and the
        engine's zero-padding invariants hold). Lazy because it is the one
        model-sized fault operand: a draw with no byzantine client returns
        ``poison=None`` and the round never materializes the array.
    """

    upload_ok: np.ndarray
    corrupt: np.ndarray | None = None
    poison: "typing.Callable | None" = None

    @property
    def n_faulted(self) -> int:
        return int((~np.asarray(self.upload_ok, bool)).sum())


class FaultModel:
    """Protocol: per-round fault draws over the client population.

    ``delays`` ([C_sel] float, seconds — each selected client's scheduled
    tau_n + tau^_n) and ``deadline`` (the round's scheduled straggler
    latency) come from the wireless bookkeeping the trainer already
    computes; models that don't need them ignore them.
    """

    def draw(self, round_index: int, n_clients: int, selected: np.ndarray,
             *, delays: np.ndarray | None = None,
             deadline: float | None = None) -> FaultDraw:
        raise NotImplementedError

    @staticmethod
    def _all_ok(n_sel: int) -> np.ndarray:
        return np.ones(n_sel, bool)


@dataclasses.dataclass(frozen=True)
class ClientDropout(FaultModel):
    """Each client independently drops its round with probability `rate`."""

    rate: float = 0.1
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"dropout rate must be in [0, 1], got {self.rate}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        u = _round_rng(self.seed, round_index, _DROPOUT).random(n_clients)
        return FaultDraw(upload_ok=u[np.asarray(selected, int)] >= self.rate)


@dataclasses.dataclass(frozen=True)
class StragglerTimeout(FaultModel):
    """Lognormal per-client slowdown against a deadline from the wireless
    delay model: client n misses the round when ``slowdown_n * delay_n >
    tolerance * deadline`` — the deadline being the round's scheduled
    straggler latency (eq. 12's per-round max), so the paper's T constraint
    is exactly the budget stragglers are judged against. With no wireless
    context (delays/deadline not supplied) nobody straggles."""

    tolerance: float = 1.5              # deadline slack factor
    sigma: float = 0.5                  # lognormal(0, sigma) slowdown spread
    seed: int = 0

    def __post_init__(self):
        if self.tolerance <= 0.0:
            raise ValueError(f"tolerance must be > 0, got {self.tolerance}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        sel = np.asarray(selected, int)
        slow = _round_rng(self.seed, round_index,
                          _STRAGGLER).lognormal(0.0, self.sigma,
                                                n_clients)[sel]
        if delays is None or deadline is None or deadline <= 0.0:
            return FaultDraw(upload_ok=self._all_ok(len(sel)))
        eff = np.asarray(delays, np.float64) * slow
        return FaultDraw(upload_ok=eff <= self.tolerance * float(deadline))


@dataclasses.dataclass(frozen=True)
class CorruptUpload(FaultModel):
    """Each arriving upload is independently corrupted with probability
    `rate`: ``mode="nan"`` poisons the gradient (quarantined by the
    engine's isfinite guard), ``mode="scale"`` multiplies it by `scale`
    (a finite deep-fade distortion that DOES reach the aggregate)."""

    rate: float = 0.05
    mode: str = "nan"                   # "nan" | "scale"
    scale: float = 100.0
    seed: int = 0

    def __post_init__(self):
        if self.mode not in ("nan", "scale"):
            raise ValueError(f"unknown corrupt mode {self.mode!r}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"corrupt rate must be in [0, 1], got {self.rate}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        sel = np.asarray(selected, int)
        u = _round_rng(self.seed, round_index, _CORRUPT).random(n_clients)[sel]
        cf = np.ones(len(sel), np.float32)
        cf[u < self.rate] = (np.float32("nan") if self.mode == "nan"
                             else np.float32(self.scale))
        return FaultDraw(upload_ok=self._all_ok(len(sel)), corrupt=cf)


@dataclasses.dataclass(frozen=True)
class MixedFaults(FaultModel):
    """Composition of the three kinds with independent per-kind streams
    (the chaos model scripts/test.sh's chaos-smoke leg runs). A kind is
    active when its knob is set: ``dropout_rate`` / ``corrupt_rate`` > 0,
    ``straggler_tolerance`` not None."""

    dropout_rate: float = 0.0
    corrupt_rate: float = 0.0
    corrupt_mode: str = "nan"
    corrupt_scale: float = 100.0
    straggler_tolerance: float | None = None
    straggler_sigma: float = 0.5
    seed: int = 0

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        sel = np.asarray(selected, int)
        ok = self._all_ok(len(sel))
        corrupt = None
        if self.dropout_rate > 0.0:
            ok &= ClientDropout(self.dropout_rate, self.seed).draw(
                round_index, n_clients, sel).upload_ok
        if self.straggler_tolerance is not None:
            ok &= StragglerTimeout(self.straggler_tolerance,
                                   self.straggler_sigma, self.seed).draw(
                round_index, n_clients, sel, delays=delays,
                deadline=deadline).upload_ok
        if self.corrupt_rate > 0.0:
            corrupt = CorruptUpload(self.corrupt_rate, self.corrupt_mode,
                                    self.corrupt_scale, self.seed).draw(
                round_index, n_clients, sel).corrupt
        return FaultDraw(upload_ok=ok, corrupt=corrupt)


# -- adversarial (byzantine) models ------------------------------------------
#
# Same draw protocol as the channel faults — a population-sized flag array
# keyed (seed, round, _BYZANTINE), indexed by the selected ids — so the
# byzantine roster at round s is a pure function of (seed, s, client id),
# invariant to selection size, dispatch grouping, and resume. The engine
# never learns who is byzantine; the defense is the robust aggregator
# (core/aggregators.py), which must bound the damage from weights alone.


def _byzantine_flags(seed: int, round_index: int, n_clients: int,
                     selected: np.ndarray, rate: float,
                     exact: bool = False) -> np.ndarray:
    """Population-level byzantine roster for one round. ``exact=False``
    flags each client independently with probability ``rate`` (a Bernoulli
    draw whose count fluctuates — at rate 0.3 over 10 clients it exceeds
    n/2, every reducer's breakdown point, in ~15% of rounds). ``exact=True``
    flags the ``round(rate * n_clients)`` clients with the smallest uniform
    draws instead: the attacker COUNT is exact every round (the standard
    f-of-n Byzantine threat model a robust aggregator is specified
    against) while the membership still rotates per round. Both modes are
    pure functions of (seed, round, client id), so they stay selection-,
    dispatch-, and resume-invariant."""
    u = _round_rng(seed, round_index, _BYZANTINE).random(n_clients)
    if exact:
        k = int(round(rate * n_clients))
        if k <= 0:
            flags = np.zeros(n_clients, bool)
        elif k >= n_clients:
            flags = np.ones(n_clients, bool)
        else:
            flags = u <= np.partition(u, k - 1)[k - 1]
    else:
        flags = u < rate
    return flags[np.asarray(selected, int)]


@dataclasses.dataclass(frozen=True)
class SignFlip(FaultModel):
    """Byzantine clients upload ``-scale * g`` — gradient ascent on the
    global objective. Rides the multiplicative `corrupt` operand (a
    ``1.0 * g`` multiply is exact, so clean clients are bitwise
    unaffected); scale=1.0 is the classic sign-flipping attack.
    ``exact=True`` pins the attacker count to round(rate * n) per round
    (see `_byzantine_flags`)."""

    rate: float = 0.1
    scale: float = 1.0
    seed: int = 0
    exact: bool = False

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"byzantine rate must be in [0, 1], "
                             f"got {self.rate}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        flags = _byzantine_flags(self.seed, round_index, n_clients,
                                 selected, self.rate, self.exact)
        cf = np.ones(len(flags), np.float32)
        cf[flags] = np.float32(-self.scale)
        return FaultDraw(upload_ok=self._all_ok(len(flags)), corrupt=cf)


@dataclasses.dataclass(frozen=True)
class ScaledMalicious(FaultModel):
    """Byzantine clients upload ``+scale * g`` — a magnitude attack that
    keeps the honest direction but dominates the mean (the canonical
    finite corruption the isfinite quarantine cannot catch). The robust
    reducers' breakdown-point property test runs against this model.
    ``exact=True`` pins the attacker count to round(rate * n) per round
    (see `_byzantine_flags`)."""

    rate: float = 0.1
    scale: float = 10.0
    seed: int = 0
    exact: bool = False

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"byzantine rate must be in [0, 1], "
                             f"got {self.rate}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        flags = _byzantine_flags(self.seed, round_index, n_clients,
                                 selected, self.rate, self.exact)
        cf = np.ones(len(flags), np.float32)
        cf[flags] = np.float32(self.scale)
        return FaultDraw(upload_ok=self._all_ok(len(flags)), corrupt=cf)


@dataclasses.dataclass(frozen=True)
class GaussianPoison(FaultModel):
    """Byzantine clients upload ``g + sigma * z``, z ~ N(0, I) over the
    packed buffer — additive noise poisoning. The per-client noise is
    drawn from an rng keyed ``(seed, round, _BYZANTINE, client_id)`` —
    client-id keyed so the draw stays selection- and dispatch-invariant —
    and returned through the draw's lazy ``poison`` callable (the engine
    materializes the [C_sel, R, L] stack only on rounds with a flagged
    client). Clean rows are exact zeros and padding lanes are masked out,
    so unflagged clients and the packed-buffer invariants are untouched."""

    rate: float = 0.1
    sigma: float = 1.0
    seed: int = 0
    exact: bool = False

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"byzantine rate must be in [0, 1], "
                             f"got {self.rate}")
        if self.sigma < 0.0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")

    def draw(self, round_index, n_clients, selected, *, delays=None,
             deadline=None) -> FaultDraw:
        sel = np.asarray(selected, int)
        flags = _byzantine_flags(self.seed, round_index, n_clients,
                                 sel, self.rate, self.exact)
        ok = self._all_ok(len(sel))
        if not flags.any():
            return FaultDraw(upload_ok=ok)
        seed, sigma, rnd = self.seed, float(self.sigma), int(round_index)

        def poison(shape, valid):
            out = np.zeros((len(sel),) + tuple(shape), np.float32)
            mask = np.asarray(valid, np.float32)
            for j in np.flatnonzero(flags):
                rng = np.random.default_rng(np.random.SeedSequence(
                    [int(seed) & 0xFFFFFFFF, rnd, _BYZANTINE, int(sel[j])]))
                out[j] = (sigma * rng.standard_normal(shape)
                          ).astype(np.float32) * mask
            return out

        # the trainer's corrupt-but-finite counter reads the roster off
        # the callable (the draw itself stays lazy)
        poison.flags = flags
        return FaultDraw(upload_ok=ok, poison=poison)
