"""Theorem 1: generalization-aware average-squared-gradient-norm bound.

    (1/(S+1)) sum_s E||grad L~(w~^(s))||^2  <=  theta({a,lambda})
      = alpha
      + beta  * sum_s 1 / (sum_n a_n^(s))
      + sum_s [ gamma1 * |sum_n a_n^(s) phi_n|^2
              + gamma2 *  sum_n a_n^(s) lambda_n^(s) ] / (sum_n a_n^(s))

with
    alpha  = 2 (L(w0) - L(w*)) / (eta (S+1))
    beta   = eta^3 A^2 (L + 1) / (Z (S+1))
    gamma1 = eta A^2 / (Z (S+1))
    gamma2 = L^2 B^2 / (S+1)

This module is the single source of truth for theta: the AO optimizer (P1) and
every benchmark evaluate exactly these functions.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class BoundConstants:
    """Assumption constants of Theorem 1."""

    lipschitz_L: float = 10.0    # Assumption 1
    grad_bound_A2: float = 10.0  # Assumption 3: E||g||^2 <= A^2   (A2 == A^2)
    model_bound_B2: float = 10.0  # Assumption 3: E||w||^2 <= B^2  (B2 == B^2)
    loss_gap: float = 10.0       # L(w^(0)) - L(w^*)
    eta: float = 0.01            # learning rate
    batch_Z: int = 32            # per-client mini-batch size
    rounds_S: int = 100          # S (the paper sums s = 0..S, i.e. S+1 rounds)

    def __post_init__(self):
        if min(self.lipschitz_L, self.grad_bound_A2, self.model_bound_B2) < 0:
            raise ValueError("assumption constants must be nonnegative")
        if self.eta <= 0 or self.batch_Z < 1 or self.rounds_S < 0:
            raise ValueError("eta>0, Z>=1, S>=0 required")

    @property
    def s_plus_1(self) -> int:
        return self.rounds_S + 1

    @property
    def alpha(self) -> float:
        return 2.0 * self.loss_gap / (self.eta * self.s_plus_1)

    @property
    def beta(self) -> float:
        return (self.eta**3) * self.grad_bound_A2 * (self.lipschitz_L + 1.0) / (
            self.batch_Z * self.s_plus_1)

    @property
    def gamma1(self) -> float:
        return self.eta * self.grad_bound_A2 / (self.batch_Z * self.s_plus_1)

    @property
    def gamma2(self) -> float:
        return (self.lipschitz_L**2) * self.model_bound_B2 / self.s_plus_1


def round_term(
    a: np.ndarray, lam: np.ndarray, phi: np.ndarray, c: BoundConstants
) -> float:
    """Per-round contribution to theta (the summand for one s).

    a:   [N] binary selection indicators.
    lam: [N] pruning ratios in [0, 1).
    phi: [N] generalization statements.
    """
    a = np.asarray(a, dtype=np.float64)
    lam = np.asarray(lam, dtype=np.float64)
    phi = np.asarray(phi, dtype=np.float64)
    n_sel = a.sum()
    if n_sel < 1:
        return float("inf")  # a round with no client makes the bound vacuous
    gen = c.gamma1 * float(np.dot(a, phi)) ** 2
    prune = c.gamma2 * float(np.dot(a, lam))
    return (c.beta + gen + prune) / float(n_sel)


def theta(
    a: np.ndarray, lam: np.ndarray, phi: np.ndarray, c: BoundConstants
) -> float:
    """Full Theorem-1 bound.

    a:   [S+1, N] selection indicators per round.
    lam: [S+1, N] pruning ratios per round.
    phi: [N]      per-client generalization statements (round-invariant, as in
                  the paper: phi_n depends only on the client's data split).
    """
    a = np.atleast_2d(np.asarray(a, dtype=np.float64))
    lam = np.atleast_2d(np.asarray(lam, dtype=np.float64))
    if a.shape != lam.shape:
        raise ValueError(f"a{a.shape} and lambda{lam.shape} must match")
    total = c.alpha
    for s in range(a.shape[0]):
        total += round_term(a[s], lam[s], phi, c)
    return float(total)


def theta_decomposition(
    a: np.ndarray, lam: np.ndarray, phi: np.ndarray, c: BoundConstants
) -> dict[str, float]:
    """theta split into its four named terms (for EXPERIMENTS.md reporting)."""
    a = np.atleast_2d(np.asarray(a, dtype=np.float64))
    lam = np.atleast_2d(np.asarray(lam, dtype=np.float64))
    phi = np.asarray(phi, dtype=np.float64)
    n_sel = a.sum(axis=1)
    if np.any(n_sel < 1):
        return {"alpha": c.alpha, "participation": float("inf"),
                "generalization": float("inf"), "pruning": float("inf"),
                "total": float("inf")}
    part = float((c.beta / n_sel).sum())
    gen = float((c.gamma1 * (a @ phi) ** 2 / n_sel).sum())
    prune = float((c.gamma2 * (a * lam).sum(axis=1) / n_sel).sum())
    return {
        "alpha": c.alpha,
        "participation": part,
        "generalization": gen,
        "pruning": prune,
        "total": c.alpha + part + gen + prune,
    }
