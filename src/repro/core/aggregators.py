"""Byzantine-robust aggregation registry (DESIGN.md §11).

The server-side reduction of the per-client gradient stack is a pluggable
axis: `SchemeSpec.aggregator` / `aggregator_kwargs` name an entry of the
`AGGREGATORS` registry below, `make_aggregator` instantiates it, and both
backends thread the instance through their aggregation tails —
`RoundEngine._aggregate_update` (packed, traced into every round graph)
and `FederatedTrainer._reference_round` (eager mirror over the same
bucket-padded stack). "mean" is the default and maps to ``None``: the
engines keep today's weighted-mean path with its traces untouched, so a
mean run stays bitwise identical to the pre-registry code (the committed
golden trajectory is the sensor).

Every robust reducer is **weight-aware**: the [C] effective weights
(0 = client-axis padding, host-dropped upload, or quarantined non-finite
client) exclude a lane from ranks, norms, and distance scores entirely,
and the survivor renormalization folds through the reducer's own mean
(kernels/ops.packed_robust_aggregate holds the math + the bitwise
contract; `reduce` returns ``(ghat, stat)`` with ghat pre-normalized for
an inv=1.0 fenced update).

This module must stay importable from core without touching repro.api
(api.registry imports core — a registry dependency here would cycle), so
the registry is a plain dict + functions rather than api.registry.Registry.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable

from repro.kernels import ops

# name -> factory(**kwargs) -> Aggregator | None (None = builtin mean path)
AGGREGATORS: dict[str, Callable] = {}


def register_aggregator(name: str, factory: Callable | None = None,
                        *, override: bool = False):
    """Register an aggregator factory (usable as a decorator). The factory
    is called with the spec's `aggregator_kwargs` and returns an
    `Aggregator` instance — or None for the builtin mean path."""
    def _register(fn):
        if not override and name in AGGREGATORS:
            raise KeyError(f"aggregator {name!r} already registered "
                           f"(pass override=True to replace)")
        AGGREGATORS[name] = fn
        return fn
    return _register(factory) if factory is not None else _register


def aggregator_names() -> list[str]:
    return sorted(AGGREGATORS)


def make_aggregator(name: str, **kwargs):
    """Instantiate a registered aggregator; returns None for "mean" (the
    engines' builtin weighted-mean path). Raises KeyError with the known
    names on an unknown aggregator, TypeError/ValueError on bad kwargs."""
    factory = AGGREGATORS.get(name)
    if factory is None:
        raise KeyError(f"unknown aggregator {name!r}; registered: "
                       f"{aggregator_names()}")
    return factory(**kwargs)


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """Base: a named, hashable robust reducer.

    `reduce(grads, cweights)` takes the packed [C, R, 128] per-client
    gradient stack (corruption factors / poison already applied) and the
    [C] effective validity weights, and returns ``(ghat, stat)`` — the
    survivor-normalized robust aggregate [R, 128] fp32 plus an int32
    per-round diagnostic count, accumulated by the trainer into the
    `stat_field` counter of ``RunResult.summary["aggregation"]``.

    `impl` picks the kernel backend for the rank-sort stage ("pallas" on
    TPU / "xla" mirror — kernels/ops semantics); distance- and norm-based
    reducers are pure jnp either way.
    """
    impl: str = "auto"
    name = "?"            # class attrs: registry key + counter routing
    stat_field = "n_excluded"

    @property
    def spec_key(self) -> str:
        """Canonical identity string — the trainer-reuse / sweep pooling
        key (api/experiment.py, api/sweep.py)."""
        return json.dumps([self.name, dataclasses.asdict(self)],
                          sort_keys=True)

    def reduce(self, grads, cweights):
        raise NotImplementedError


@register_aggregator("mean")
def _mean(**kwargs):
    if kwargs:
        raise TypeError(f"mean takes no kwargs, got {sorted(kwargs)}")
    return None


@dataclasses.dataclass(frozen=True)
class CoordMedian(Aggregator):
    """Coordinate-wise median over valid clients (rank sort per lane)."""
    name = "coord_median"

    def reduce(self, grads, cweights):
        return ops.packed_robust_aggregate(grads, cweights,
                                           kind="coord_median",
                                           impl=self.impl)


@dataclasses.dataclass(frozen=True)
class TrimmedMean(Aggregator):
    """Per-coordinate beta-trimmed mean: drop the floor(beta*n) smallest
    and largest values, mean the middle. Breakdown point beta: any f <
    floor(beta*n) arbitrarily-scaled attackers land in the trimmed tails
    (tests/test_aggregators.py property test)."""
    beta: float = 0.1
    name = "trimmed_mean"
    stat_field = "n_trimmed"

    def __post_init__(self):
        if not 0.0 <= float(self.beta) < 0.5:
            raise ValueError(
                f"trimmed_mean beta must be in [0, 0.5), got {self.beta}")

    def reduce(self, grads, cweights):
        return ops.packed_robust_aggregate(grads, cweights,
                                           kind="trimmed_mean",
                                           beta=float(self.beta),
                                           impl=self.impl)


@dataclasses.dataclass(frozen=True)
class NormClip(Aggregator):
    """Mean of norm-clipped uploads: client c scales by min(1,
    tau/||g_c||). tau=None (or <= 0) adapts per round to the median of
    the valid clients' norms — scale attacks clip down to honest
    magnitude without tuning a threshold."""
    tau: float | None = None
    name = "norm_clip"
    stat_field = "n_clipped"

    def reduce(self, grads, cweights):
        return ops.packed_robust_aggregate(
            grads, cweights, kind="norm_clip",
            tau=None if self.tau is None else float(self.tau),
            impl=self.impl)


@dataclasses.dataclass(frozen=True)
class MultiKrum(Aggregator):
    """Multi-Krum (Blanchard et al.): score each valid client by the sum
    of its n-f-2 smallest squared distances to the others, keep the m
    (default n-f) lowest-scoring clients, mean them. f is the assumed
    attacker budget; outliers — far from every honest cluster — score
    high and are excluded."""
    f: int = 1
    m: int | None = None

    name = "multi_krum"

    def __post_init__(self):
        if int(self.f) < 0:
            raise ValueError(f"multi_krum f must be >= 0, got {self.f}")
        if self.m is not None and int(self.m) < 1:
            raise ValueError(f"multi_krum m must be >= 1, got {self.m}")

    def reduce(self, grads, cweights):
        return ops.packed_robust_aggregate(
            grads, cweights, kind="multi_krum", f=int(self.f),
            m=None if self.m is None else int(self.m), impl=self.impl)


register_aggregator("coord_median", CoordMedian)
register_aggregator("trimmed_mean", TrimmedMean)
register_aggregator("norm_clip", NormClip)
register_aggregator("multi_krum", MultiKrum)
