"""Local-update scheme configuration (FedAvg / FedProx / FedDyn).

A :class:`LocalScheme` describes what each client does *between* uploads:
how many local gradient steps it runs and which per-step regularizer it
applies.  The packed engine consumes this as static trace metadata — the
scheme name and the pow2-bucketed step count both enter the trace-family
key, so the number of compiled programs stays bounded exactly like the
client/blocklength buckets from PR 2/3.

``make_local_scheme("fedavg", steps=1)`` returns ``None``: plain
single-step FedAvg *is* today's FedSGD, and returning ``None`` routes
every caller through the untouched single-gradient code paths so the
committed goldens are protected by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

_SCHEMES = ("fedavg", "fedprox", "feddyn")


@dataclasses.dataclass(frozen=True)
class LocalScheme:
    """Static description of the client-local update rule.

    name:  one of ``fedavg`` / ``fedprox`` / ``feddyn``.
    steps: number of local gradient steps per round (E >= 1).
    mu:    FedProx proximal coefficient (ignored otherwise).
    alpha: FedDyn regularization coefficient (ignored otherwise).
    """

    name: str
    steps: int
    mu: float = 0.0
    alpha: float = 0.0

    @property
    def steps_bucket(self) -> int:
        """Pow2 bucket the step axis pads to (part of the trace key)."""
        return 1 << (self.steps - 1).bit_length()

    @property
    def stateful(self) -> bool:
        """Whether the scheme carries per-client [R,128] state (FedDyn)."""
        return self.name == "feddyn"

    @property
    def coeff(self) -> float:
        """The per-step (u - u0) coefficient: mu / alpha / 0."""
        if self.name == "fedprox":
            return float(self.mu)
        if self.name == "feddyn":
            return float(self.alpha)
        return 0.0

    @property
    def spec_key(self):
        """Hashable identity used in trainer-pool / reuse keys."""
        return (self.name, int(self.steps), float(self.mu), float(self.alpha))


def make_local_scheme(
    name: str = "fedavg", steps: int = 1, **kwargs
) -> Optional[LocalScheme]:
    """Resolve a local-scheme config; ``None`` means the trivial FedSGD path.

    Unknown kwargs are rejected so sweep-grid typos fail loudly.
    """
    if name not in _SCHEMES:
        raise ValueError(
            f"unknown local scheme {name!r}; expected one of {_SCHEMES}"
        )
    steps = int(steps)
    if steps < 1:
        raise ValueError(f"local_steps must be >= 1, got {steps}")
    mu = float(kwargs.pop("mu", 0.0))
    alpha = float(kwargs.pop("alpha", 0.0))
    if kwargs:
        raise ValueError(f"unknown local scheme kwargs: {sorted(kwargs)}")
    if name == "fedprox" and mu < 0.0:
        raise ValueError(f"fedprox mu must be >= 0, got {mu}")
    if name == "feddyn" and alpha < 0.0:
        raise ValueError(f"feddyn alpha must be >= 0, got {alpha}")
    if name == "fedavg" and steps == 1:
        return None
    return LocalScheme(name=name, steps=steps, mu=mu, alpha=alpha)


def local_spec_key(scheme: Optional[LocalScheme]):
    """Pool-key fragment for a possibly-``None`` scheme."""
    return ("fedsgd",) if scheme is None else scheme.spec_key
