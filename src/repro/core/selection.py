"""(P4)/(P5): client selection, and per-client data selection.

Per-round objective (Theorem 1 summand):

    J_s(a) = ( beta + gamma1 |sum_n a_n phi_n|^2 + gamma2 sum_n a_n lambda_n )
             / sum_n a_n

subject to the round's energy/delay feasibility. Two solvers:

* `method="exact"` (beyond-paper): with N <= `EXACT_LIMIT` clients the
  per-round subproblem is enumerated over all 2^N - 1 subsets — globally
  optimal per round. Round coupling through the shared budgets is handled by
  an energy-price bisection (Lagrangian on the total-energy row), which is
  exact when rounds are exchangeable (constant channels, as in the paper).
* `method="paper"`: the paper's alternation on (a, mu): fix mu = current
  quadratic+pruning term, relax a to [0,1], solve the resulting program by
  projected gradient, round by threshold sweep, update mu; iterate until the
  objective stops decreasing (Sec. IV-B-3).

Per-client DATA selection (`data_selection_*`, beyond the paper): Albaseer
et al. ("Fine-Grained Data Selection for Improved Energy Efficiency of
Federated Edge Learning") have each client train on a curated subset of its
local samples — excluding marginal/noisy ones — to cut per-round energy at
matched accuracy. Reproduced here as deterministic per-client sample
filters applied ONCE per run, before training: each sample is scored by its
squared distance to its class centroid within the client's own shard (a
model-free typicality proxy), and a policy keeps either the samples under a
relative score threshold (`threshold`) or a fixed fraction of the most
typical ones (`fine_grained`). Static filtering composes with the packed /
block engines untouched — smaller clients simply ride the existing ragged
path — so the axis adds zero per-round host work (the experiment API wires
it through `SchemeSpec.data_selection`).
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.convergence import BoundConstants
from repro.core.resource import solve_round_resources
from repro.wireless.comm import SystemParams

EXACT_LIMIT = 16


# ---------------------------------------------------------------------------
# Per-client data selection (Albaseer-style threshold / fine-grained filters)
# ---------------------------------------------------------------------------

def data_selection_scores(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Per-sample atypicality: squared distance to the sample's class
    centroid, computed within the client's own shard. Deterministic in
    (x, y); lower = more typical. Classes with a single sample score 0."""
    y = np.asarray(y)
    if len(y) == 0:
        return np.zeros(0, np.float64)
    x = np.asarray(x, np.float64).reshape(len(y), -1)
    scores = np.zeros(len(y), np.float64)
    for cls in np.unique(y):
        idx = np.flatnonzero(y == cls)
        centroid = x[idx].mean(axis=0)
        scores[idx] = ((x[idx] - centroid) ** 2).sum(axis=1)
    return scores


def data_selection_keep_mask(
    x: np.ndarray, y: np.ndarray, *, policy: str, tau: float = 1.5,
    keep_frac: float = 0.8,
) -> np.ndarray:
    """Boolean keep-mask for one client's samples under a selection policy.

    ``policy="threshold"``: keep samples whose score is <= tau * mean
    score (relative threshold — scale-free across clients with very
    different shard sizes / spreads). ``policy="fine_grained"``: keep the
    ``ceil(keep_frac * n)`` most typical samples (ties broken by original
    order via a stable argsort). Both always keep at least one sample, and
    kept samples preserve their original order, so the filtered shard is
    reproducible and independent of any RNG."""
    scores = data_selection_scores(x, y)
    n = len(scores)
    if policy == "threshold":
        if tau <= 0:
            raise ValueError(f"tau must be > 0, got {tau}")
        keep = scores <= tau * (scores.mean() if n else 0.0)
    elif policy == "fine_grained":
        if not 0.0 < keep_frac <= 1.0:
            raise ValueError(f"keep_frac must be in (0, 1], got {keep_frac}")
        k = max(1, int(np.ceil(keep_frac * n)))
        keep = np.zeros(n, bool)
        keep[np.argsort(scores, kind="stable")[:k]] = True
    else:
        raise ValueError(f"unknown data-selection policy {policy!r}")
    if not keep.any() and n:
        keep[int(np.argmin(scores))] = True
    return keep


def round_objective(
    a: np.ndarray, lam: np.ndarray, phi: np.ndarray, c: BoundConstants,
    coupling: str = "sum",
) -> float:
    """Per-round selection objective.

    coupling="sum":  the literal Theorem-1 summand — gamma1 |sum a phi|^2 / n.
      Its quadratic growth in the number of selected clients makes the exact
      minimizer degenerate to the single lowest-phi client (EXPERIMENTS.md
      §Paper findings).
    coupling="mean": gamma1 * (mean selected phi)^2 — the normalized variant
      that recovers the paper's reported multi-client behavior."""
    n_sel = float(np.sum(a))
    if n_sel < 1:
        return float("inf")
    quad = c.gamma1 * float(np.dot(a, phi)) ** 2
    if coupling == "mean":
        quad /= n_sel ** 2
    return (c.beta + quad + c.gamma2 * float(np.dot(a, lam))) / n_sel


def _subset_feasible(
    a: np.ndarray, lam: np.ndarray, t_round: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> tuple[bool, float]:
    """Check a candidate round subset against the per-round delay budget and
    return its min-energy cost (for the energy price)."""
    ra = solve_round_resources(a, lam, t_round, h_up, h_down, sp)
    return ra.feasible, ra.energy


def _per_client_table(
    lam: np.ndarray, t_round: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-client (feasible, min-energy) under the round budget.

    Given the per-round delay budget, client allocations are independent
    (FDMA: no shared uplink resource beyond the pre-assigned bandwidth), so a
    subset is feasible iff every member is, and its energy is the sum. This
    turns the 2^N enumeration into vector ops.
    """
    from repro.core.resource import allocate_client
    n = len(lam)
    feas = np.zeros(n, dtype=bool)
    energy = np.zeros(n)
    for i in range(n):
        al = allocate_client(i, float(lam[i]), t_round, h_up, h_down, sp)
        feas[i], energy[i] = al.feasible, al.energy
    return feas, energy


def select_round_exact(
    lam: np.ndarray, phi: np.ndarray, c: BoundConstants,
    t_round: float, energy_price: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
    coupling: str = "sum",
) -> tuple[np.ndarray, float, float]:
    """Enumerate subsets; minimize J_s(a) + price * E_s(a). Returns (a, J, E)."""
    n = len(phi)
    if n > EXACT_LIMIT:
        return select_round_greedy(lam, phi, c, t_round, energy_price,
                                   h_up, h_down, sp, coupling)
    from repro.wireless.comm import broadcast_energy
    feas_n, energy_n = _per_client_table(lam, t_round, h_up, h_down, sp)
    e_bc = broadcast_energy(h_down, sp)
    best_a, best_score, best_j, best_e = None, float("inf"), float("inf"), 0.0
    for bits in range(1, 2**n):
        idx = [(bits >> i) & 1 for i in range(n)]
        a = np.array(idx, dtype=np.float64)
        mask = a > 0
        if not feas_n[mask].all():
            continue
        energy = float(energy_n[mask].sum()) + e_bc
        j = round_objective(a, lam, phi, c, coupling)
        score = j + energy_price * energy
        if score < best_score:
            best_a, best_score, best_j, best_e = a, score, j, energy
    if best_a is None:  # nothing feasible: pick the single fastest client
        from repro.core.resource import min_client_delay
        delays = [min_client_delay(i, float(lam[i]), h_up, h_down, sp)
                  for i in range(n)]
        a = np.zeros(n)
        a[int(np.argmin(delays))] = 1.0
        feas, energy = _subset_feasible(a, lam, t_round, h_up, h_down, sp)
        return a, round_objective(a, lam, phi, c), energy
    return best_a, best_j, best_e


def select_round_greedy(
    lam: np.ndarray, phi: np.ndarray, c: BoundConstants,
    t_round: float, energy_price: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
    coupling: str = "sum",
) -> tuple[np.ndarray, float, float]:
    """Greedy add-in-phi-order with local swaps — used when N > EXACT_LIMIT."""
    from repro.wireless.comm import broadcast_energy
    n = len(phi)
    feas_n, energy_n = _per_client_table(lam, t_round, h_up, h_down, sp)
    e_bc = broadcast_energy(h_down, sp)
    order = [i for i in np.argsort(phi) if feas_n[i]]
    if not order:
        order = [int(np.argmin(energy_n))]
    a = np.zeros(n)
    best_score, best_a, best_e = float("inf"), None, 0.0
    for k in order:
        a[k] = 1.0
        energy = float(energy_n[a > 0].sum()) + e_bc
        score = round_objective(a, lam, phi, c, coupling) + energy_price * energy
        if score < best_score:
            best_score, best_a, best_e = score, a.copy(), energy
    if best_a is None:
        best_a = np.zeros(n)
        best_a[order[0]] = 1.0
        best_e = float(energy_n[order[0]]) + e_bc
    return best_a, round_objective(best_a, lam, phi, c, coupling), best_e


def solve_selection(
    lam: np.ndarray, phi: np.ndarray, c: BoundConstants,
    e0: float, t0: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
    *, method: str = "exact", coupling: str = "sum",
) -> tuple[np.ndarray, dict]:
    """Solve selection for the whole schedule. lam: [S+1, N]. Returns a, info.

    Budget coupling: per-round delay budget t0/(S+1); total energy met by
    bisecting a scalar energy price nu >= 0 in J_s + nu * E_s.
    """
    lam = np.atleast_2d(lam)
    n_rounds, n = lam.shape
    t_round = t0 / max(n_rounds, 1)
    solver = {"exact": select_round_exact, "paper": select_round_paper,
              "greedy": select_round_greedy}[method]

    def run(price: float):
        a_all, e_tot, j_tot = [], 0.0, 0.0
        memo: dict[bytes, tuple] = {}  # identical lam rows => identical round
        for s in range(n_rounds):
            key = lam[s].tobytes()
            if key not in memo:
                memo[key] = solver(lam[s], phi, c, t_round, price,
                                   h_up, h_down, sp, coupling)
            a, j, e = memo[key]
            a_all.append(a)
            e_tot += e
            j_tot += j
        return np.array(a_all), e_tot, j_tot

    a, e_tot, j_tot = run(0.0)
    price = 0.0
    if e_tot > e0:
        lo, hi = 0.0, 1.0
        _, e_hi, _ = run(hi)
        while e_hi > e0 and hi < 1e12:
            hi *= 10.0
            _, e_hi, _ = run(hi)
        for _ in range(40):
            mid = 0.5 * (lo + hi)
            a_m, e_m, j_m = run(mid)
            if e_m > e0:
                lo = mid
            else:
                hi = mid
                a, e_tot, j_tot, price = a_m, e_m, j_m, mid
    return a, {"energy": e_tot, "objective": j_tot, "energy_price": price,
               "feasible": e_tot <= e0 * (1 + 1e-6)}


def select_round_paper(
    lam: np.ndarray, phi: np.ndarray, c: BoundConstants,
    t_round: float, energy_price: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
    coupling: str = "sum", *, iters: int = 20,
) -> tuple[np.ndarray, float, float]:
    """(P5) paper-faithful alternation between a (relaxed+rounded) and mu.

    With mu fixed, the objective sum_s (beta + mu)/sum a is minimized by
    selecting *more* clients; with a fixed, mu tightens to the quadratic term.
    We sweep thresholds on phi (the relaxed problem's optimal structure sorts
    clients by phi), keeping the best feasible rounding — this is the paper's
    iterative scheme made concrete.
    """
    from repro.wireless.comm import broadcast_energy
    n = len(phi)
    feas_n, energy_n = _per_client_table(lam, t_round, h_up, h_down, sp)
    e_bc = broadcast_energy(h_down, sp)
    order = [i for i in np.argsort(phi) if feas_n[i]]
    if not order:
        order = [int(np.argmin(energy_n))]
    mu = 0.0
    best = (None, float("inf"), 0.0)
    for _ in range(iters):
        improved = False
        for k in range(1, len(order) + 1):
            a = np.zeros(n)
            a[order[:k]] = 1.0
            energy = float(energy_n[a > 0].sum()) + e_bc
            quad = c.gamma1 * float(np.dot(a, phi)) ** 2
            if coupling == "mean":
                quad /= a.sum() ** 2
            quad += c.gamma2 * float(np.dot(a, lam))
            score = (c.beta + max(quad, mu)) / a.sum() + energy_price * energy
            if score < best[1]:
                best = (a, score, energy)
                mu = quad
                improved = True
        if not improved:
            break
    if best[0] is None:
        a = np.zeros(n)
        a[order[0]] = 1.0
        best = (a, round_objective(a, lam, phi, c, coupling),
                float(energy_n[order[0]]) + e_bc)
    a = best[0]
    return a, round_objective(a, lam, phi, c, coupling), best[2]
