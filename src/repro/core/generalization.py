"""Information-theoretic generalization statement (Lemma 1 / Proposition 1).

The paper defines, per client n, the *generalization statement*

    phi_n = (D_hat_n + D_til_n) / p'(z|D_hat_n)
            * | sqrt(2 (H(p(z|D_til_n)) - I(p(z|D_hat_n), p(z|D_til_n))))
                / (1 - D_til_n * sqrt(2 (H(p~) - I(p^,p~)))) |

where (eq. 38 of the paper) the entropy/mutual-information combination collapses
to a KL divergence between the train and test label distributions:

    H(p~) - I(p^, p~) = KL(p^ || p~),
    with I(p, q) := H(p) + H(q) - CE(p, q)   (CE = cross-entropy).

Small phi_n  <=>  the client's local training distribution is aligned with the
test distribution  <=>  its updates generalize; the selection problem (P4/P5)
prefers such clients.

All quantities are computed from empirical *label* histograms, exactly how the
paper's Dirichlet(sigma) non-IID simulation induces heterogeneity (Fig. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

_EPS = 1e-12


def _as_dist(p: np.ndarray) -> np.ndarray:
    """Normalize a nonnegative histogram into a probability vector."""
    p = np.asarray(p, dtype=np.float64)
    if p.ndim != 1:
        raise ValueError(f"distribution must be 1-D, got shape {p.shape}")
    if np.any(p < 0):
        raise ValueError("histogram has negative mass")
    tot = p.sum()
    if tot <= 0:
        raise ValueError("histogram has zero mass")
    return p / tot


def entropy(p: Sequence[float]) -> float:
    """Shannon entropy H(p) in nats."""
    p = _as_dist(np.asarray(p))
    nz = p > _EPS
    return float(-(p[nz] * np.log(p[nz])).sum())


def cross_entropy(p: Sequence[float], q: Sequence[float]) -> float:
    """Cross entropy CE(p, q) = -sum p log q (nats). Infinite if supp(p) !<= supp(q)."""
    p, q = _as_dist(np.asarray(p)), _as_dist(np.asarray(q))
    if p.shape != q.shape:
        raise ValueError("distributions must share support size")
    nz = p > _EPS
    if np.any(q[nz] <= _EPS):
        return float("inf")
    return float(-(p[nz] * np.log(q[nz])).sum())


def mutual_information_term(p_train: Sequence[float], p_test: Sequence[float]) -> float:
    """I(p^, p~) := H(p^) + H(p~) - CE(p^, p~), the paper's eq. (38) decomposition."""
    return entropy(p_train) + entropy(p_test) - cross_entropy(p_train, p_test)


def kl_divergence(p: Sequence[float], q: Sequence[float]) -> float:
    """KL(p || q) in nats, = H(q-term) - I in the paper's decomposition."""
    p, q = _as_dist(np.asarray(p)), _as_dist(np.asarray(q))
    nz = p > _EPS
    if np.any(q[nz] <= _EPS):
        return float("inf")
    return float((p[nz] * (np.log(p[nz]) - np.log(q[nz]))).sum())


@dataclasses.dataclass(frozen=True)
class GeneralizationStatement:
    """phi_n plus its constituent terms, for reporting (Fig. 3 reproduction)."""

    phi: float
    kl: float                 # KL(p_train || p_test) = H(p~) - I(p^,p~)
    entropy_test: float       # H(p(z|D~))
    mutual_information: float  # I(p^, p~)
    p_min_train: float        # p'(z|D_hat): least-frequent *present* train prob
    d_train: int
    d_test: int


def generalization_statement(
    train_hist: Sequence[float],
    test_hist: Sequence[float],
    *,
    d_train: int | None = None,
    d_test: int | None = None,
    size_normalized: bool = True,
) -> GeneralizationStatement:
    """Compute phi_n (Lemma 1) from train/test label histograms.

    Args:
      train_hist: per-class sample counts of the client's training split D_hat_n.
      test_hist:  per-class sample counts of the (sampled) test split D_til_n.
      d_train/d_test: dataset sizes D_hat_n / D_til_n; default = histogram mass.
      size_normalized: the raw Lemma-1 constant uses the absolute dataset sizes
        (D_hat+D_til) and 1 - D_til*sqrt(.); with thousands of samples the raw
        value saturates for every client and loses all discriminative power. The
        paper's own Fig. 3 plots client-distinguishing phi values, which requires
        the *size-normalized* variant (sizes expressed as fractions of the global
        dataset). Both are available; `size_normalized=True` is what the
        selection optimizer consumes.

    Returns the statement with diagnostics. phi is clipped to [0, PHI_MAX] for
    degenerate supports (disjoint train/test support => KL = inf => phi -> cap).
    """
    th = np.asarray(train_hist, dtype=np.float64)
    eh = np.asarray(test_hist, dtype=np.float64)
    # histograms may carry fractional mass (proportions); sizes round up
    d_tr = int(np.ceil(th.sum())) if d_train is None else int(d_train)
    d_te = int(np.ceil(eh.sum())) if d_test is None else int(d_test)
    if d_tr <= 0 or d_te <= 0:
        raise ValueError("empty train or test split")

    p_tr = _as_dist(th)
    p_te = _as_dist(eh)
    h_test = entropy(p_te)
    mi = mutual_information_term(p_tr, p_te)
    kl = kl_divergence(p_tr, p_te)  # == h_test - mi up to fp error when finite

    present = p_tr > _EPS
    p_min = float(p_tr[present].min())

    if size_normalized:
        tot = float(d_tr + d_te)
        size_sum = (d_tr + d_te) / tot          # == 1; relative scale
        d_til = d_te / tot
    else:
        size_sum = float(d_tr + d_te)
        d_til = float(d_te)

    if not np.isfinite(kl):
        phi = PHI_MAX
    else:
        root = np.sqrt(max(2.0 * kl, 0.0))
        denom = 1.0 - d_til * root
        if abs(denom) < _EPS:
            phi = PHI_MAX
        else:
            phi = (size_sum / p_min) * abs(root / denom)
            phi = float(min(phi, PHI_MAX))
    return GeneralizationStatement(
        phi=float(phi), kl=float(kl), entropy_test=h_test,
        mutual_information=float(mi), p_min_train=p_min,
        d_train=d_tr, d_test=d_te,
    )


#: Cap applied when the Lemma-1 constant blows up (disjoint supports / denom ~ 0).
PHI_MAX = 1e6


def client_statements(
    train_hists: np.ndarray, test_hists: np.ndarray, **kw
) -> list[GeneralizationStatement]:
    """Vector helper: one statement per client row."""
    train_hists = np.atleast_2d(np.asarray(train_hists))
    test_hists = np.atleast_2d(np.asarray(test_hists))
    if test_hists.shape[0] == 1 and train_hists.shape[0] > 1:
        test_hists = np.broadcast_to(test_hists, train_hists.shape)
    return [
        generalization_statement(tr, te, **kw)
        for tr, te in zip(train_hists, test_hists)
    ]


def phis(train_hists: np.ndarray, test_hists: np.ndarray, **kw) -> np.ndarray:
    """Just the phi values, shape [N]."""
    return np.array([s.phi for s in client_statements(train_hists, test_hists, **kw)])


def generalization_gap_increment_bound(
    selected_phis: np.ndarray, eta: float, grad_sq_norm: float
) -> float:
    """Proposition 1: bound on phi^{(s+1)} - phi^{(s)} (generalization-gap drift).

        0.5 * (eta^2 + |sum_n a_n phi_n|^2) * E||G(w~)||^2

    `selected_phis` are the phi_n of the *selected* clients only.
    """
    s = float(np.sum(selected_phis))
    return 0.5 * (eta**2 + s * s) * float(grad_sq_norm)
