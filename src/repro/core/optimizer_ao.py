"""Algorithm 1: alternating optimization for (P1).

Iterates, for o = 1..O:
  1. (P2.1) resources {p, f}   given {a, lambda}    — SCA / analytic min-energy
  2. (P3)   pruning {lambda}   given {a, p, f}      — exact LP (HiGHS)
  3. (P5)   selection {a}      given {lambda, p, f} — exact enumeration or the
                                                      paper's iterative scheme
keeping the incumbent with the smallest theta among feasible iterates
(the paper: "Obtain the final solution leading to non-increasing objective").
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.convergence import BoundConstants, theta, theta_decomposition
from repro.core.ratio import solve_pruning_ratios
from repro.core.resource import solve_schedule_resources
from repro.core.selection import solve_selection
from repro.wireless.comm import SystemParams, total_delay, total_energy


@dataclasses.dataclass
class Schedule:
    """The optimizer's output: the full per-round system schedule."""

    a: np.ndarray       # [S+1, N] selection
    lam: np.ndarray     # [S+1, N] pruning ratios
    power: np.ndarray   # [S+1, N] W
    freq: np.ndarray    # [S+1, N] Hz
    theta: float
    energy: float
    delay: float
    feasible: bool
    history: list = dataclasses.field(default_factory=list)

    def decomposition(self, phi: np.ndarray, c: BoundConstants) -> dict:
        return theta_decomposition(self.a, self.lam, phi, c)


@dataclasses.dataclass(frozen=True)
class AOConfig:
    outer_iters: int = 6
    selection_method: str = "exact"   # "exact" | "paper" | "greedy"
    tol: float = 1e-6
    # Benchmark-scheme ablations (paper Sec. V baselines):
    fix_lambda: float | None = None   # "fixed pruning": lambda forced
    fix_selection: bool = False       # "fixed selection": a_n = 1 forall n
    use_phi: bool = True              # "without generalization statement"
    fix_power: float | None = None    # "fixed power design": p_n forced [W]
    fix_freq: bool = False            # "fixed clock": f_n = f_max
    phi_coupling: str = "sum"         # "sum" (Thm-1 literal) | "mean"


def solve_random(
    phi: np.ndarray,
    e0: float,
    t0: float,
    h_up: np.ndarray,
    h_down: np.ndarray,
    sp: SystemParams,
    c: BoundConstants,
    *,
    k: int,
    lam: float = 0.0,
    seed: int = 0,
) -> Schedule:
    """Fleet-scale baseline: k clients uniformly at random per round, fixed
    pruning ratio, max power/clock. Every step is a vectorized [S+1, N]
    draw/broadcast, so it stays O(N) where Algorithm 1's subproblems run
    per-client scalar solves — the scheme that makes 1e5+ populations
    schedulable (registry name "random_k")."""
    n = len(phi)
    n_rounds = c.rounds_S + 1
    k = max(1, min(int(k), n))
    rng = np.random.default_rng(seed & 0xFFFFFFFF)
    a = np.zeros((n_rounds, n))
    for s in range(n_rounds):
        a[s, rng.choice(n, size=k, replace=False)] = 1.0
    lam_arr = np.full((n_rounds, n), float(lam))
    p = np.broadcast_to(np.asarray(sp.p_max, float), (n_rounds, n)).copy()
    f = np.broadcast_to(np.asarray(sp.f_max, float), (n_rounds, n)).copy()
    th = theta(a, lam_arr, phi, c)
    e_tot = total_energy(a, lam_arr, p, f, h_up, h_down, sp)
    t_tot = total_delay(a, lam_arr, p, f, h_up, h_down, sp)
    feas = e_tot <= e0 * (1 + 1e-4) and t_tot <= t0 * (1 + 1e-4)
    return Schedule(a, lam_arr, p, f, th, e_tot, t_tot, feas,
                    history=[{"iter": 0, "theta": th, "energy": e_tot,
                              "delay": t_tot, "feasible": feas}])


def solve_p1(
    phi: np.ndarray,
    e0: float,
    t0: float,
    h_up: np.ndarray,
    h_down: np.ndarray,
    sp: SystemParams,
    c: BoundConstants,
    cfg: AOConfig = AOConfig(),
    *,
    a_init: np.ndarray | None = None,
    lam_init: np.ndarray | None = None,
) -> Schedule:
    """Run Algorithm 1 and return the best feasible schedule found."""
    n = len(phi)
    n_rounds = c.rounds_S + 1
    phi_opt = phi if cfg.use_phi else np.zeros_like(phi)
    a = np.ones((n_rounds, n)) if a_init is None else np.atleast_2d(a_init).astype(float)
    if cfg.fix_lambda is not None:
        lam = cfg.fix_lambda * np.ones((n_rounds, n))
    else:
        # start unpruned: theta is increasing in lambda, so lambda should
        # only rise if the budgets force it (initializing at lambda_max
        # lets (P2) stretch the schedule and then traps (P3) at the max)
        lam = (np.zeros((n_rounds, n)) if lam_init is None
               else np.atleast_2d(lam_init).astype(float))

    def overrides(p, f):
        if cfg.fix_power is not None:
            p = np.full_like(p, cfg.fix_power)
        if cfg.fix_freq:
            f = np.broadcast_to(sp.f_max, f.shape).copy()
        return p, f

    best: Schedule | None = None
    history = []
    for o in range(cfg.outer_iters):
        # --- (P2): resources given (a, lam)
        p, f, rinfo = solve_schedule_resources(a, lam, e0, t0, h_up, h_down, sp)
        p, f = overrides(p, f)
        # --- (P3): pruning ratios given (a, p, f)
        if cfg.fix_lambda is None:
            lam, linfo = solve_pruning_ratios(a, p, f, e0, t0, h_up, h_down,
                                              sp, c)
            p, f, rinfo = solve_schedule_resources(a, lam, e0, t0, h_up,
                                                   h_down, sp)
            p, f = overrides(p, f)
        # --- (P5): selection given (lam, p, f)
        if not cfg.fix_selection:
            a, sinfo = solve_selection(lam, phi_opt, c, e0, t0, h_up, h_down,
                                       sp, method=cfg.selection_method,
                                       coupling=cfg.phi_coupling)
            # selection changed the active set: lambdas/resources for newly
            # selected clients must exist -> one more (P3)+(P2) pass
            if cfg.fix_lambda is None:
                lam, _ = solve_pruning_ratios(a, p, f, e0, t0, h_up, h_down,
                                              sp, c)
            p, f, rinfo = solve_schedule_resources(a, lam, e0, t0, h_up,
                                                   h_down, sp)
            p, f = overrides(p, f)

        th = theta(a, lam, phi, c)
        e_tot = total_energy(a, lam, p, f, h_up, h_down, sp)
        t_tot = total_delay(a, lam, p, f, h_up, h_down, sp)
        feas = e_tot <= e0 * (1 + 1e-4) and t_tot <= t0 * (1 + 1e-4)
        history.append({"iter": o, "theta": th, "energy": e_tot,
                        "delay": t_tot, "feasible": feas})
        cand = Schedule(a.copy(), lam.copy(), p.copy(), f.copy(),
                        th, e_tot, t_tot, feas)
        if feas and (best is None or th < best.theta - cfg.tol * abs(best.theta)):
            best = cand
        elif best is not None and feas and th >= best.theta - cfg.tol * abs(best.theta):
            break  # non-increasing objective converged
        if best is None:
            best = cand  # keep something even if infeasible (reported as such)
    best.history = history
    return best
